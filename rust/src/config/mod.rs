//! Configuration: model presets, optimization configs, engine/scheduler
//! settings, and the artifact manifest schema.
//!
//! The five model presets and five opt configs mirror
//! `python/compile/presets.py`; at runtime the authoritative copy is
//! `artifacts/manifest.json` (written by `python -m compile.aot`), which
//! [`Manifest::load`] parses — the rust presets exist for paper-scale
//! geometry (platform model) and for tests that run without artifacts.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// Which of the paper's optimizations are active (mirrors `OptConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptConfig {
    pub name: &'static str,
    /// Opt-KV read path: FP8 (E4M3) cache + per-slot scales
    pub fp8_kv: bool,
    /// Opt-KV write path: engine emits -1 slots for SkipSet members (Eq. 5)
    pub skip_filter: bool,
    /// Opt-GQA: grouped-query attention (Eq. 7)
    pub gqa: bool,
    /// Opt-Pa: valid-block-only attention loop (Eq. 9)
    pub valid_only: bool,
    /// Opt-Pa step 1 (segmentation): serve prefill in bounded chunks
    /// interleaved with the decode batch.  Orthogonal to the kernel
    /// configs — the five named configs keep it off so the AOT graph set
    /// is unchanged; engines enable it per-deployment via
    /// [`EngineConfig::with_chunked_prefill`].
    pub chunked_prefill: bool,
}

pub const ORIGINAL: OptConfig = OptConfig {
    name: "original",
    fp8_kv: false,
    skip_filter: false,
    gqa: false,
    valid_only: false,
    chunked_prefill: false,
};
pub const OPTKV: OptConfig = OptConfig {
    name: "optkv",
    fp8_kv: true,
    skip_filter: true,
    gqa: false,
    valid_only: false,
    chunked_prefill: false,
};
pub const OPTGQA: OptConfig = OptConfig {
    name: "optgqa",
    fp8_kv: false,
    skip_filter: false,
    gqa: true,
    valid_only: false,
    chunked_prefill: false,
};
pub const OPTPA: OptConfig = OptConfig {
    name: "optpa",
    fp8_kv: false,
    skip_filter: false,
    gqa: false,
    valid_only: true,
    chunked_prefill: false,
};
pub const COOPT: OptConfig = OptConfig {
    name: "coopt",
    fp8_kv: true,
    skip_filter: true,
    gqa: true,
    valid_only: true,
    chunked_prefill: false,
};

pub const ALL_CONFIGS: [OptConfig; 5] = [ORIGINAL, OPTKV, OPTGQA, OPTPA, COOPT];

pub fn opt_config(name: &str) -> Result<OptConfig> {
    ALL_CONFIGS
        .iter()
        .find(|c| c.name == name)
        .copied()
        .ok_or_else(|| anyhow!("unknown opt config '{name}' (expected one of original/optkv/optgqa/optpa/coopt)"))
}

/// Sim-scale model description (mirrors `ModelPreset`), including the
/// paper-scale twin geometry used by the Z100 platform model.
#[derive(Debug, Clone)]
pub struct ModelPreset {
    pub name: String,
    pub stands_for: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads_gqa: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub head_dim: usize,
    // paper-scale twin
    pub paper_layers: usize,
    pub paper_d_model: usize,
    pub paper_heads: usize,
}

impl ModelPreset {
    pub fn n_kv_heads(&self, gqa: bool) -> usize {
        if gqa {
            self.n_kv_heads_gqa
        } else {
            self.n_heads
        }
    }

    /// Query heads per KV head (Eq. 7's H_g).
    pub fn groups(&self, gqa: bool) -> usize {
        self.n_heads / self.n_kv_heads(gqa)
    }

    /// Approximate parameter count of the sim model.
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let hd = self.head_dim;
        let per_layer = d * self.n_heads * hd * 2 // wq, wo
            + d * self.n_heads * hd * 2           // wk/wv mha
            + d * self.n_kv_heads_gqa * hd * 2    // wk/wv gqa
            + 3 * d * self.ffn
            + 2 * d;
        self.vocab * d * 2 + self.layers * per_layer + d
    }
}

/// Built-in presets (kept in sync with python; tests cross-check against
/// the manifest when artifacts exist).
pub fn builtin_presets() -> Vec<ModelPreset> {
    let mk = |name: &str, stands_for: &str, layers, d_model, n_heads, n_kv, ffn,
              paper_layers, paper_d, paper_heads| ModelPreset {
        name: name.into(),
        stands_for: stands_for.into(),
        layers,
        d_model,
        n_heads,
        n_kv_heads_gqa: n_kv,
        ffn,
        vocab: 260,
        head_dim: 32,
        paper_layers,
        paper_d_model: paper_d,
        paper_heads,
    };
    vec![
        mk("llama-7b-sim", "LLaMa-7B-GPTQ", 3, 128, 4, 2, 352, 32, 4096, 32),
        mk("llama2-7b-sim", "LLaMa2-7B-GPTQ", 3, 128, 4, 2, 384, 32, 4096, 32),
        mk("llama-13b-sim", "LLaMa-13B-GPTQ", 4, 192, 6, 2, 512, 40, 5120, 40),
        mk("llama2-13b-sim", "LLaMa2-13B-GPTQ", 4, 192, 6, 2, 544, 40, 5120, 40),
        mk("llama-pro-8b-sim", "LLaMa-Pro-8B-GPTQ", 4, 160, 5, 1, 448, 40, 4096, 32),
    ]
}

pub fn builtin_preset(name: &str) -> Result<ModelPreset> {
    builtin_presets()
        .into_iter()
        .find(|p| p.name == name)
        .ok_or_else(|| anyhow!("unknown model preset '{name}'"))
}

/// Paged-cache geometry (shared constants with python presets; the
/// manifest overrides these at runtime).
#[derive(Debug, Clone, Copy)]
pub struct CacheGeometry {
    pub block_size: usize,
    pub max_blocks: usize,
    pub num_pool_blocks: usize,
    pub max_batch: usize,
    pub max_seq: usize,
}

impl Default for CacheGeometry {
    fn default() -> Self {
        CacheGeometry {
            block_size: 16,
            max_blocks: 10,
            num_pool_blocks: 96,
            max_batch: 8,
            max_seq: 128,
        }
    }
}

impl CacheGeometry {
    pub fn max_context(&self) -> usize {
        self.block_size * self.max_blocks
    }
}

/// How preemption exits when the device KV pool is exhausted and a host
/// tier is configured (Opt-KV tier manager; deployment knob like
/// `chunked_prefill`, so the five named opt configs are unaffected).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SwapPolicy {
    /// cost-based: swap when the PCIe round trip is cheaper than
    /// recomputing the victim's prefill (the platform model decides)
    #[default]
    Auto,
    /// always swap when the host tier has capacity
    Always,
    /// never swap: drop-and-recompute (the single-tier baseline)
    Never,
}

impl SwapPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(SwapPolicy::Auto),
            "always" => Ok(SwapPolicy::Always),
            "never" => Ok(SwapPolicy::Never),
            other => Err(anyhow!(
                "unknown swap policy '{other}' (expected auto|always|never)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SwapPolicy::Auto => "auto",
            SwapPolicy::Always => "always",
            SwapPolicy::Never => "never",
        }
    }
}

/// How the multi-replica router places incoming requests across its N
/// engines (a serve-time deployment knob like [`SwapPolicy`]; with one
/// replica every policy degenerates to the same choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// rotate over non-draining replicas (the load-blind baseline)
    RoundRobin,
    /// lowest load score: estimated outstanding tokens plus queue depth,
    /// discounted by the replica's measured service speed
    /// (`tokens_per_step` / `spec_regime` gauges) and inflated by its KV
    /// pressure (free device/host blocks)
    #[default]
    LeastLoaded,
    /// prefer the replica whose KV cache already holds the prompt's
    /// leading block-aligned prefix (cluster-level Opt-KV reuse);
    /// falls back to least-loaded when following affinity would push the
    /// cross-replica load imbalance above the cost model's threshold,
    /// so one hot prefix cannot wedge a replica
    PrefixAffinity,
    /// prefix-affinity placement driven by the cluster prefix
    /// *directory* (full chain depth + residency tier, not just the
    /// leading block), plus cross-replica KV **pulls**: when the owner
    /// is elsewhere and `CostModel::prefix_pull_pays` prices the PCIe
    /// transfer under re-prefilling, the destination pulls the chain's
    /// blocks before prefill instead of recomputing them
    Directory,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 4] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::PrefixAffinity,
        RouterPolicy::Directory,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round_robin" => Ok(RouterPolicy::RoundRobin),
            "least_loaded" => Ok(RouterPolicy::LeastLoaded),
            "prefix_affinity" => Ok(RouterPolicy::PrefixAffinity),
            "directory" => Ok(RouterPolicy::Directory),
            other => Err(anyhow!(
                "unknown router policy '{other}' \
                 (expected round_robin|least_loaded|prefix_affinity|directory)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::LeastLoaded => "least_loaded",
            RouterPolicy::PrefixAffinity => "prefix_affinity",
            RouterPolicy::Directory => "directory",
        }
    }
}

/// What phases of work a replica accepts in a disaggregated
/// prefill/decode (PD) cluster.  A serve-time deployment knob like
/// [`RouterPolicy`]: the default (`Mixed` everywhere) keeps PR 5's
/// uniform cluster, and a single engine ignores its role entirely.
///
/// A `Prefill` replica runs prompts to prefill completion and then
/// hands the sequence off to a decode-capable replica, migrating its
/// KV blocks through the host tier when the cost model says the PCIe
/// round trip beats re-prefilling on the destination.  A `Decode`
/// replica is kept out of the prefill-heavy placement set so long
/// prompts cannot stall its inter-token latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaRole {
    /// runs prefill, hands sequences off at prefill completion
    Prefill,
    /// preferred target for decode work and migrated sequences
    Decode,
    /// accepts both phases (the uniform-cluster baseline and the
    /// fallback when migration doesn't pay)
    #[default]
    Mixed,
}

impl ReplicaRole {
    pub const ALL: [ReplicaRole; 3] = [
        ReplicaRole::Prefill,
        ReplicaRole::Decode,
        ReplicaRole::Mixed,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "prefill" => Ok(ReplicaRole::Prefill),
            "decode" => Ok(ReplicaRole::Decode),
            "mixed" => Ok(ReplicaRole::Mixed),
            other => Err(anyhow!(
                "unknown replica role '{other}' (expected prefill|decode|mixed)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
            ReplicaRole::Mixed => "mixed",
        }
    }

    /// Whether this role accepts new prefill-phase placements.
    pub fn accepts_prefill(&self) -> bool {
        !matches!(self, ReplicaRole::Decode)
    }

    /// Whether this role can own a sequence through its decode phase.
    pub fn accepts_decode(&self) -> bool {
        !matches!(self, ReplicaRole::Prefill)
    }
}

/// Parse a comma-separated role list (`--replica-roles`), e.g.
/// `prefill,decode,mixed`.  An empty string means no role overrides
/// (every replica stays `Mixed`).
pub fn parse_replica_roles(s: &str) -> Result<Vec<ReplicaRole>> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    s.split(',').map(|r| ReplicaRole::parse(r.trim())).collect()
}

/// Request priority class for SLO-aware serving.  `Interactive` is the
/// default so untagged traffic keeps the pre-SLO behaviour exactly: when
/// every request is the same class, the class-aware orderings (waiting /
/// swapped / preemption victim) degenerate to the classic stamp orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Priority {
    /// latency-sensitive: protected by admission control, scheduled ahead
    /// of batch work, never the preferred preemption victim
    #[default]
    Interactive,
    /// throughput work: first to be shed under overload, last in the
    /// waiting/swapped orderings, preferred preemption/swap victim
    Batch,
}

impl Priority {
    pub const ALL: [Priority; 2] = [Priority::Interactive, Priority::Batch];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(anyhow!(
                "unknown priority class '{other}' (expected interactive|batch)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    pub fn is_interactive(&self) -> bool {
        matches!(self, Priority::Interactive)
    }
}

/// Per-request SLO annotation threaded through the whole request path
/// (`/v1/generate` → router admission → scheduler orderings → deadline
/// enforcement at step boundaries → per-class latency attribution).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReqClass {
    pub priority: Priority,
    /// hard deadline relative to arrival; a request past it is cancelled
    /// at the next step boundary instead of finishing uselessly
    pub deadline_ms: Option<u64>,
    /// tenant id for per-tenant token-rate accounting (None = untenanted)
    pub tenant: Option<String>,
}

impl ReqClass {
    pub fn interactive() -> Self {
        ReqClass {
            priority: Priority::Interactive,
            ..ReqClass::default()
        }
    }

    pub fn batch() -> Self {
        ReqClass {
            priority: Priority::Batch,
            ..ReqClass::default()
        }
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }
}

/// SLO-aware overload-control knobs (router admission + scheduler
/// reservations + deadline enforcement).  The default (`admission`
/// off, reserve 0) keeps every pre-SLO behaviour bit-identical; the
/// serve-time flags `--slo-admission`, `--slo-interactive-ttft-ms`,
/// and `--interactive-prefill-reserve` opt a deployment in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// router admission control on/off (`--slo-admission`): shed batch
    /// work with 429 + Retry-After when the projected queue wait would
    /// blow the interactive TTFT budget, bound the batch queue, and cap
    /// any tenant's share of outstanding prefill tokens
    pub admission: bool,
    /// interactive TTFT budget in milliseconds
    /// (`--slo-interactive-ttft-ms`): the admission controller sheds or
    /// defers batch work when the projected queue wait exceeds it
    pub interactive_ttft_ms: u64,
    /// fraction of the per-step prefill budget reserved for interactive
    /// sequences while any interactive prefill is pending
    /// (`--interactive-prefill-reserve`, clamped to `0.0..=0.9`); 0
    /// disables the split
    pub interactive_prefill_reserve: f64,
    /// max share of the cluster's outstanding prefill tokens one tenant
    /// may hold before its *batch* work is shed (interactive work is
    /// never tenant-shed while batch is queued)
    pub tenant_share: f64,
    /// bounded batch queue: batch admissions beyond this many outstanding
    /// batch requests are shed immediately
    pub max_batch_queue: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            admission: false,
            interactive_ttft_ms: 250,
            interactive_prefill_reserve: 0.0,
            tenant_share: 0.5,
            max_batch_queue: 16,
        }
    }
}

/// Predictive-telemetry knobs (`rust/src/obs/forecast.rs`): the signal
/// ring plus the three self-scoring estimators — per-tenant output
/// length, arrival bursts, queue wait — and the calibration band that
/// gates whether controllers may consume them.  The default (`enabled`
/// off) keeps every reactive behaviour bit-identical; `--forecast`
/// opts a deployment in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ForecastConfig {
    /// master switch (`--forecast`): off means no sampling, no stamps,
    /// no estimator state — the pre-forecast reactive path, exactly
    pub enabled: bool,
    /// bounded signal-ring capacity in step-boundary samples
    /// (`--forecast-ring`)
    pub ring: usize,
    /// resolved predictions an estimator needs before its forecasts may
    /// be consumed (`--forecast-warmup`); predictions are stamped and
    /// scored from the first request either way
    pub warmup: u64,
    /// coverage band `[lo, hi]`: a length/wait estimator is consumable
    /// only while the fraction of recent actuals landing under its
    /// predicted bound sits inside the band
    pub coverage_lo: f64,
    pub coverage_hi: f64,
    /// burst detection threshold (`--forecast-burst-ratio`): short-window
    /// arrival rate must be at least this multiple of the long-window
    /// rate
    pub burst_ratio: f64,
    /// admission tightening factor while a scored burst is active
    /// (`--forecast-burst-tighten`): divides the batch-queue bound and
    /// multiplies the projected wait
    pub burst_tighten: f64,
    /// proactive-eviction watermark (free device blocks) raised to this
    /// floor while a scored burst is active — clears headroom ahead of
    /// the burst even when `--evict-watermark` is lower or off
    pub burst_watermark: usize,
    /// EWMA smoothing for the calibration error / drain-rate /
    /// acceptance folds
    pub ewma_alpha: f64,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            enabled: false,
            ring: 256,
            warmup: 16,
            coverage_lo: 0.8,
            coverage_hi: 1.0,
            burst_ratio: 2.0,
            burst_tighten: 2.0,
            burst_watermark: 4,
            ewma_alpha: 0.2,
        }
    }
}

/// Acceptance rule for speculative decoding (draft-and-verify).
///
/// Greedy requests (temperature 0) always verify by exact argmax match
/// regardless of policy — speculation is output-preserving there by
/// construction.  The policy chooses what happens for *sampled*
/// requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpecPolicy {
    /// deterministic-verification override: every position (accepted,
    /// corrected, and the bonus commit) is the target argmax even for
    /// temperature>0 requests — a reproducibility/throughput mode that
    /// intentionally overrides sampling during speculation
    Greedy,
    /// standard rejection sampling over the same filtered candidate set
    /// `sample` uses (accept with prob min(1, p/q), sample the residual
    /// on reject) — preserves the target sampling distribution, top-k
    /// and top-p included, given the `Backend::draft` contract (each
    /// proposal distributed as its reported logits; a deterministic
    /// draft chain reports a point mass); the default
    #[default]
    Stochastic,
}

impl SpecPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "greedy" => Ok(SpecPolicy::Greedy),
            "stochastic" => Ok(SpecPolicy::Stochastic),
            other => Err(anyhow!(
                "unknown spec policy '{other}' (expected greedy|stochastic)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpecPolicy::Greedy => "greedy",
            SpecPolicy::Stochastic => "stochastic",
        }
    }
}

/// How the draft length k is chosen (speculative decoding).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpecMode {
    /// the PR-3 behaviour: `draft_tokens` is the draft length every
    /// round (a construction-time constant)
    #[default]
    Fixed,
    /// closed-loop: a per-step controller picks k from the measured
    /// acceptance rate (EWMA, global + per-sequence) and the cost
    /// model's regime detector
    /// ([`crate::platform::CostModel::best_draft_len`]), bounded by
    /// `k_max`; k = 0 (plain decode) when the batch is GEMM-bound or
    /// acceptance collapses
    Adaptive,
}

impl SpecMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fixed" => Ok(SpecMode::Fixed),
            "adaptive" => Ok(SpecMode::Adaptive),
            other => Err(anyhow!(
                "unknown spec mode '{other}' (expected fixed|adaptive)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpecMode::Fixed => "fixed",
            SpecMode::Adaptive => "adaptive",
        }
    }
}

/// Speculative decoding (draft-and-verify) deployment knobs.  Like
/// `chunked_prefill` and the host pool, this is orthogonal to the five
/// named opt configs: the default (`Fixed` mode, `draft_tokens == 0`)
/// keeps the one-token decode path and the AOT graph set unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecConfig {
    /// fixed-mode draft length k: tokens proposed per running sequence
    /// per decode round; a verify pass scores k+1 positions and commits
    /// the accepted prefix plus one corrected/bonus token.  0 disables
    /// speculation in `Fixed` mode (adaptive mode ignores this and
    /// searches `0..=k_max` online).
    pub draft_tokens: usize,
    /// draft model size as a fraction of the target (the platform model
    /// streams draft weights at this fraction of the target's bytes on
    /// every draft micro-step)
    pub shrink: f64,
    /// acceptance rule (greedy token match or stochastic rejection
    /// sampling)
    pub policy: SpecPolicy,
    /// fixed vs adaptive draft-length selection
    pub mode: SpecMode,
    /// adaptive mode: upper bound of the per-round draft-length search
    /// (0 disables speculation in adaptive mode)
    pub k_max: usize,
    /// adaptive mode: EWMA smoothing factor of the acceptance-rate
    /// estimator (weight of the newest round; clamped to (0, 1])
    pub ewma_alpha: f64,
    /// adaptive mode: per-position acceptance below which a lane (or the
    /// whole controller) is instantly demoted to plain decode
    pub demote_acceptance: f64,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            draft_tokens: 0,
            shrink: 0.125,
            policy: SpecPolicy::Stochastic,
            mode: SpecMode::Fixed,
            k_max: 4,
            ewma_alpha: 0.25,
            demote_acceptance: 0.25,
        }
    }
}

impl SpecConfig {
    /// Whether any speculative path is configured (fixed k > 0, or
    /// adaptive with a non-zero search bound).
    pub fn enabled(&self) -> bool {
        match self.mode {
            SpecMode::Fixed => self.draft_tokens > 0,
            SpecMode::Adaptive => self.k_max > 0,
        }
    }

    /// Largest draft length a round may use (the scheduler's worst-case
    /// budget charge and the engine's reservation bound).
    pub fn max_draft(&self) -> usize {
        match self.mode {
            SpecMode::Fixed => self.draft_tokens,
            SpecMode::Adaptive => self.k_max,
        }
    }

    /// Disable speculation entirely (the backend-degradation path).
    pub fn disable(&mut self) {
        self.draft_tokens = 0;
        self.k_max = 0;
        self.mode = SpecMode::Fixed;
    }
}

/// Engine/scheduler tunables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub opt: OptConfig,
    /// max sequences decoded together (<= manifest max_batch)
    pub max_batch: usize,
    /// shared per-step token budget: decode slots plus prefill tokens
    /// committed in one scheduling round.  One-shot mode additionally
    /// refuses to admit prompts longer than this; chunked mode splits
    /// them instead.
    pub max_prefill_tokens: usize,
    /// Opt-Pa step 1: segment prefill into chunks and interleave them
    /// with decode batches (bounds decode inter-token stalls)
    pub chunked_prefill: bool,
    /// per-chunk token cap when `chunked_prefill` is on
    pub prefill_chunk_tokens: usize,
    /// Opt-KV tier manager: host-tier capacity in blocks (0 disables the
    /// two-tier hierarchy; preemption then always drops and recomputes)
    pub host_pool_blocks: usize,
    /// swap-vs-recompute preemption policy (only meaningful with a host
    /// pool and a backend that supports KV swap)
    pub swap_policy: SwapPolicy,
    /// watermark-based proactive eviction (`--evict-watermark`): when
    /// device free blocks dip below this floor, the engine swaps the
    /// preemption-order victim's sole-owner blocks to the host tier
    /// ahead of demand (one victim per step, swap-only).  0 — the
    /// default — disables it; demand preemption alone reclaims blocks.
    pub evict_watermark: usize,
    /// Opt-KV tier manager: how many decode batches' worth of swapped
    /// sequences the async prefetch queue may stage ahead of the
    /// scheduler (the ROADMAP's multi-step prefetch depth knob; 1 — the
    /// default — stages what the next step's batch can absorb, deeper
    /// values trade device blocks for hidden swap latency)
    pub prefetch_depth: usize,
    /// speculative decoding (draft-and-verify) knobs; `spec.draft_tokens
    /// == 0` keeps the one-token decode path.  Backends without
    /// draft/verify support degrade to one-token decode at construction.
    pub spec: SpecConfig,
    /// PD disaggregation: what phases this engine accepts when it runs
    /// behind the router (`Mixed` = the uniform-cluster default; a
    /// standalone engine ignores its role)
    pub role: ReplicaRole,
    /// default sampling params
    pub max_new_tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub top_p: f64,
    pub seed: u64,
    /// flight-recorder capacity in finished-request timelines kept per
    /// replica for `GET /admin/trace` (`--trace-depth`; 0 disables the
    /// recorder — phase attribution in `/metrics` stays on)
    pub trace_depth: usize,
    /// fraction of requests whose per-event timeline is recorded
    /// (`--trace-sample`, deterministic per request id); phase breakdowns
    /// and histograms are always exact regardless of sampling
    pub trace_sample: f64,
    /// SLO-aware overload control (admission shedding, interactive
    /// prefill reservation, deadline enforcement); defaults keep every
    /// pre-SLO behaviour
    pub slo: SloConfig,
    /// predictive telemetry plane (signal ring + self-scoring length /
    /// burst / wait estimators); defaults keep every reactive behaviour
    pub forecast: ForecastConfig,
}

impl EngineConfig {
    pub fn new(model: &str, opt: OptConfig) -> Self {
        EngineConfig {
            model: model.to_string(),
            opt,
            max_batch: 8,
            max_prefill_tokens: 256,
            chunked_prefill: opt.chunked_prefill,
            prefill_chunk_tokens: 32,
            host_pool_blocks: 0,
            swap_policy: SwapPolicy::Auto,
            evict_watermark: 0,
            prefetch_depth: 1,
            spec: SpecConfig::default(),
            role: ReplicaRole::Mixed,
            max_new_tokens: 32,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            trace_depth: 64,
            trace_sample: 1.0,
            slo: SloConfig::default(),
            forecast: ForecastConfig::default(),
        }
    }

    /// Enable chunked prefill with a per-chunk token cap.
    pub fn with_chunked_prefill(mut self, chunk_tokens: usize) -> Self {
        self.chunked_prefill = true;
        self.prefill_chunk_tokens = chunk_tokens.max(1);
        self
    }

    /// Override the shared per-step token budget.
    pub fn with_step_budget(mut self, tokens: usize) -> Self {
        self.max_prefill_tokens = tokens.max(1);
        self
    }

    /// Attach a host tier of `blocks` KV blocks (Opt-KV tier manager):
    /// preemption may swap a victim's blocks over PCIe instead of
    /// dropping them and recomputing its prefill.
    pub fn with_host_pool(mut self, blocks: usize) -> Self {
        self.host_pool_blocks = blocks;
        self
    }

    /// Choose the swap-vs-recompute preemption policy.
    pub fn with_swap_policy(mut self, policy: SwapPolicy) -> Self {
        self.swap_policy = policy;
        self
    }

    /// Enable watermark-based proactive eviction: swap ahead of demand
    /// whenever device free blocks dip below `blocks`.
    pub fn with_evict_watermark(mut self, blocks: usize) -> Self {
        self.evict_watermark = blocks;
        self
    }

    /// Cap the swap-ins the async prefetch queue stages ahead per step.
    pub fn with_prefetch_depth(mut self, depth: usize) -> Self {
        self.prefetch_depth = depth.max(1);
        self
    }

    /// Enable speculative decoding with a draft length of `k` tokens per
    /// round (a verify pass can commit up to k+1 tokens).
    pub fn with_speculation(mut self, k: usize) -> Self {
        self.spec.draft_tokens = k;
        self
    }

    /// Choose the speculative acceptance rule.
    pub fn with_spec_policy(mut self, policy: SpecPolicy) -> Self {
        self.spec.policy = policy;
        self
    }

    /// Enable *adaptive* speculation: a per-step controller picks the
    /// draft length in `0..=k_max` from the measured acceptance rate and
    /// the cost model's regime detector (`--spec-mode adaptive`).
    pub fn with_adaptive_speculation(mut self, k_max: usize) -> Self {
        self.spec.mode = SpecMode::Adaptive;
        self.spec.k_max = k_max;
        self
    }

    /// Adaptive speculation: EWMA smoothing factor of the acceptance
    /// estimator (weight of the newest round).
    pub fn with_spec_ewma_alpha(mut self, alpha: f64) -> Self {
        self.spec.ewma_alpha = alpha.clamp(0.01, 1.0);
        self
    }

    /// Adaptive speculation: acceptance threshold below which a lane (or
    /// the controller) is instantly demoted to plain decode.
    pub fn with_spec_demote_acceptance(mut self, a: f64) -> Self {
        self.spec.demote_acceptance = a.clamp(0.0, 1.0);
        self
    }

    /// Set the draft model's size as a fraction of the target (drives the
    /// platform model's draft-weight restream cost).
    pub fn with_spec_shrink(mut self, shrink: f64) -> Self {
        self.spec.shrink = shrink.clamp(0.01, 1.0);
        self
    }

    /// Assign this engine's PD role (`--replica-roles`).
    pub fn with_role(mut self, role: ReplicaRole) -> Self {
        self.role = role;
        self
    }

    /// Size the flight-recorder ring (`--trace-depth`; 0 disables it).
    pub fn with_trace_depth(mut self, depth: usize) -> Self {
        self.trace_depth = depth;
        self
    }

    /// Set the per-request event-timeline sampling rate
    /// (`--trace-sample`, clamped to `0.0..=1.0`).
    pub fn with_trace_sample(mut self, s: f64) -> Self {
        self.trace_sample = s.clamp(0.0, 1.0);
        self
    }

    /// Enable router admission control (`--slo-admission`).
    pub fn with_slo_admission(mut self, on: bool) -> Self {
        self.slo.admission = on;
        self
    }

    /// Set the interactive TTFT budget (`--slo-interactive-ttft-ms`).
    pub fn with_interactive_ttft_ms(mut self, ms: u64) -> Self {
        self.slo.interactive_ttft_ms = ms.max(1);
        self
    }

    /// Reserve a fraction of the per-step prefill budget for interactive
    /// sequences (`--interactive-prefill-reserve`, clamped to
    /// `0.0..=0.9` so batch prefill always keeps a sliver of budget).
    pub fn with_interactive_prefill_reserve(mut self, frac: f64) -> Self {
        self.slo.interactive_prefill_reserve = frac.clamp(0.0, 0.9);
        self
    }

    /// Cap one tenant's share of outstanding prefill tokens (clamped to
    /// `0.05..=1.0`; 1.0 disables the cap).
    pub fn with_tenant_share(mut self, share: f64) -> Self {
        self.slo.tenant_share = share.clamp(0.05, 1.0);
        self
    }

    /// Bound the batch queue: batch admissions beyond this many
    /// outstanding batch requests are shed.
    pub fn with_max_batch_queue(mut self, n: usize) -> Self {
        self.slo.max_batch_queue = n.max(1);
        self
    }

    /// Enable the predictive telemetry plane (`--forecast`).
    pub fn with_forecast(mut self, on: bool) -> Self {
        self.forecast.enabled = on;
        self
    }

    /// Size the forecast signal ring (`--forecast-ring`).
    pub fn with_forecast_ring(mut self, samples: usize) -> Self {
        self.forecast.ring = samples.max(1);
        self
    }

    /// Resolved predictions required before a forecast may be consumed
    /// (`--forecast-warmup`).
    pub fn with_forecast_warmup(mut self, n: u64) -> Self {
        self.forecast.warmup = n.max(1);
        self
    }

    /// Calibration coverage band `[lo, hi]` outside which controllers
    /// fall back to the reactive path.
    pub fn with_forecast_coverage(mut self, lo: f64, hi: f64) -> Self {
        self.forecast.coverage_lo = lo.clamp(0.0, 1.0);
        self.forecast.coverage_hi = hi.clamp(self.forecast.coverage_lo, 1.0);
        self
    }

    /// Burst detection threshold (`--forecast-burst-ratio`, clamped to
    /// `>= 1.0`): short-window arrival rate over long-window rate.
    pub fn with_forecast_burst_ratio(mut self, r: f64) -> Self {
        self.forecast.burst_ratio = r.max(1.0);
        self
    }

    /// Admission tightening factor while a scored burst is active
    /// (`--forecast-burst-tighten`, clamped to `>= 1.0`).
    pub fn with_forecast_burst_tighten(mut self, t: f64) -> Self {
        self.forecast.burst_tighten = t.max(1.0);
        self
    }

    /// Proactive-eviction watermark floor while a scored burst is
    /// active (`--forecast-burst-watermark`).
    pub fn with_forecast_burst_watermark(mut self, blocks: usize) -> Self {
        self.forecast.burst_watermark = blocks;
        self
    }
}

// ---------------------------------------------------------------------------
// artifact manifest
// ---------------------------------------------------------------------------

/// One weight array's layout inside `<model>.weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub offset: usize,
    pub nbytes: usize,
    pub shape: Vec<usize>,
}

/// One lowered graph (model x config x phase).
#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub model: String,
    pub config: String,
    pub phase: String,
    pub file: String,
    /// weight parameters this graph references, in positional order
    /// (XLA DCEs unused checkpoint entries, so this can be a strict
    /// subset of the model's weight list)
    pub weights: Vec<String>,
    /// runtime (non-weight) inputs in positional order after the weights
    pub runtime_inputs: Vec<TensorSpec>,
    pub num_outputs: usize,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32" | "u8"
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-model manifest record.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub preset: ModelPreset,
    pub weights_file: String,
    pub weights: Vec<WeightEntry>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub geometry: CacheGeometry,
    pub models: Vec<ModelEntry>,
    pub graphs: Vec<GraphEntry>,
    pub eval_sets: Vec<(String, String)>, // (split, file)
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let geometry = CacheGeometry {
            block_size: v.req_usize("block_size")?,
            max_blocks: v.req_usize("max_blocks")?,
            num_pool_blocks: v.req_usize("num_pool_blocks")?,
            max_batch: v.req_usize("max_batch")?,
            max_seq: v.req_usize("max_seq")?,
        };

        let mut models = Vec::new();
        let model_obj = v
            .req("models")?
            .as_object()
            .ok_or_else(|| anyhow!("manifest 'models' is not an object"))?;
        for (name, m) in model_obj.iter() {
            let preset = ModelPreset {
                name: name.to_string(),
                stands_for: m.req_str("stands_for")?.to_string(),
                layers: m.req_usize("layers")?,
                d_model: m.req_usize("d_model")?,
                n_heads: m.req_usize("n_heads")?,
                n_kv_heads_gqa: m.req_usize("n_kv_heads_gqa")?,
                ffn: m.req_usize("ffn")?,
                vocab: m.req_usize("vocab")?,
                head_dim: m.req_usize("head_dim")?,
                paper_layers: m.req_usize("paper_layers")?,
                paper_d_model: m.req_usize("paper_d_model")?,
                paper_heads: m.req_usize("paper_heads")?,
            };
            let weights = m
                .req_array("weights")?
                .iter()
                .map(|w| {
                    Ok(WeightEntry {
                        name: w.req_str("name")?.to_string(),
                        offset: w.req_usize("offset")?,
                        nbytes: w.req_usize("nbytes")?,
                        shape: shape_vec(w.req("shape")?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.push(ModelEntry {
                preset,
                weights_file: m.req_str("weights_file")?.to_string(),
                weights,
            });
        }

        let graphs = v
            .req_array("graphs")?
            .iter()
            .map(|g| {
                let runtime_inputs = g
                    .req_array("runtime_inputs")?
                    .iter()
                    .map(|t| {
                        Ok(TensorSpec {
                            name: t.req_str("name")?.to_string(),
                            dtype: t.req_str("dtype")?.to_string(),
                            shape: shape_vec(t.req("shape")?)?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let weights = g
                    .req_array("weights")?
                    .iter()
                    .map(|w| {
                        w.as_str()
                            .map(|s| s.to_string())
                            .ok_or_else(|| anyhow!("graph weight name not a string"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(GraphEntry {
                    model: g.req_str("model")?.to_string(),
                    config: g.req_str("config")?.to_string(),
                    phase: g.req_str("phase")?.to_string(),
                    file: g.req_str("file")?.to_string(),
                    weights,
                    runtime_inputs,
                    num_outputs: g.req_usize("num_outputs")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut eval_sets = Vec::new();
        if let Some(es) = v.get("eval_sets").and_then(|e| e.as_object()) {
            for (k, val) in es.iter() {
                eval_sets.push((
                    k.to_string(),
                    val.as_str()
                        .ok_or_else(|| anyhow!("eval_sets value not a string"))?
                        .to_string(),
                ));
            }
        }

        if models.is_empty() || graphs.is_empty() {
            bail!("manifest has no models/graphs");
        }
        Ok(Manifest {
            dir,
            geometry,
            models,
            graphs,
            eval_sets,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.preset.name == name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn graph(&self, model: &str, config: &str, phase: &str) -> Result<&GraphEntry> {
        self.graphs
            .iter()
            .find(|g| g.model == model && g.config == config && g.phase == phase)
            .ok_or_else(|| anyhow!("graph {model}/{config}/{phase} not in manifest"))
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.preset.name.clone()).collect()
    }
}

fn shape_vec(v: &Value) -> Result<Vec<usize>> {
    v.as_array()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape element")))
        .collect()
}

/// Default artifacts dir: `$LLM_COOPT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LLM_COOPT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_consistent() {
        for p in builtin_presets() {
            assert_eq!(p.d_model, p.n_heads * p.head_dim, "{}", p.name);
            assert_eq!(p.n_heads % p.n_kv_heads_gqa, 0, "{}", p.name);
            assert!(p.param_count() > 100_000);
            assert_eq!(p.groups(false), 1);
            assert_eq!(p.groups(true), p.n_heads / p.n_kv_heads_gqa);
        }
    }

    #[test]
    fn opt_config_lookup() {
        assert!(opt_config("coopt").unwrap().fp8_kv);
        assert!(opt_config("coopt").unwrap().valid_only);
        assert!(!opt_config("original").unwrap().gqa);
        assert!(opt_config("bogus").is_err());
        // optpa only flips the block loop
        let pa = opt_config("optpa").unwrap();
        assert!(pa.valid_only && !pa.fp8_kv && !pa.gqa && !pa.skip_filter);
    }

    #[test]
    fn chunked_prefill_knobs() {
        // the named configs keep chunking off (graph set unchanged)...
        for c in ALL_CONFIGS {
            assert!(!c.chunked_prefill, "{}", c.name);
        }
        // ...and engines opt in per-deployment
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert!(!cfg.chunked_prefill);
        let cfg = cfg.with_chunked_prefill(16).with_step_budget(48);
        assert!(cfg.chunked_prefill);
        assert_eq!(cfg.prefill_chunk_tokens, 16);
        assert_eq!(cfg.max_prefill_tokens, 48);
        // degenerate values are clamped to something runnable
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_chunked_prefill(0);
        assert_eq!(cfg.prefill_chunk_tokens, 1);
    }

    #[test]
    fn host_tier_knobs() {
        // off by default: single-tier drop-and-recompute
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert_eq!(cfg.host_pool_blocks, 0);
        assert_eq!(cfg.swap_policy, SwapPolicy::Auto);
        let cfg = cfg.with_host_pool(64).with_swap_policy(SwapPolicy::Always);
        assert_eq!(cfg.host_pool_blocks, 64);
        assert_eq!(cfg.swap_policy, SwapPolicy::Always);
        // parse round-trips
        for p in [SwapPolicy::Auto, SwapPolicy::Always, SwapPolicy::Never] {
            assert_eq!(SwapPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SwapPolicy::parse("bogus").is_err());
    }

    #[test]
    fn trace_knobs() {
        // tracing on by default: full sampling, 64-deep flight recorder
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert_eq!(cfg.trace_depth, 64);
        assert!((cfg.trace_sample - 1.0).abs() < 1e-12);
        let cfg = cfg.with_trace_depth(8).with_trace_sample(0.25);
        assert_eq!(cfg.trace_depth, 8);
        assert!((cfg.trace_sample - 0.25).abs() < 1e-12);
        // 0 disables the recorder; sample clamps into [0, 1]
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_trace_depth(0)
            .with_trace_sample(7.0);
        assert_eq!(cfg.trace_depth, 0);
        assert!((cfg.trace_sample - 1.0).abs() < 1e-12);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_trace_sample(-3.0);
        assert!(cfg.trace_sample.abs() < 1e-12);
    }

    #[test]
    fn speculation_knobs() {
        // off by default: one-token decode, graph set unchanged
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert_eq!(cfg.spec.draft_tokens, 0);
        assert_eq!(
            cfg.spec.policy,
            SpecPolicy::Stochastic,
            "distribution-preserving by default"
        );
        assert_eq!(cfg.prefetch_depth, 1);
        let cfg = cfg
            .with_speculation(4)
            .with_spec_policy(SpecPolicy::Greedy)
            .with_spec_shrink(0.25)
            .with_prefetch_depth(3);
        assert_eq!(cfg.spec.draft_tokens, 4);
        assert_eq!(cfg.spec.policy, SpecPolicy::Greedy);
        assert!((cfg.spec.shrink - 0.25).abs() < 1e-12);
        assert_eq!(cfg.prefetch_depth, 3);
        // degenerate values are clamped to something runnable
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_spec_shrink(0.0)
            .with_prefetch_depth(0);
        assert!(cfg.spec.shrink > 0.0);
        assert_eq!(cfg.prefetch_depth, 1);
        // parse round-trips
        for p in [SpecPolicy::Greedy, SpecPolicy::Stochastic] {
            assert_eq!(SpecPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SpecPolicy::parse("bogus").is_err());
    }

    #[test]
    fn adaptive_speculation_knobs() {
        // default: fixed mode, speculation off, adaptive knobs at their
        // documented defaults
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert_eq!(cfg.spec.mode, SpecMode::Fixed);
        assert!(!cfg.spec.enabled());
        assert_eq!(cfg.spec.k_max, 4);
        assert!((cfg.spec.ewma_alpha - 0.25).abs() < 1e-12);
        assert!((cfg.spec.demote_acceptance - 0.25).abs() < 1e-12);
        // fixed mode: draft_tokens is the bound
        let fixed = cfg.clone().with_speculation(3);
        assert!(fixed.spec.enabled());
        assert_eq!(fixed.spec.max_draft(), 3);
        // adaptive mode: k_max is the bound, draft_tokens is ignored
        let ad = cfg
            .clone()
            .with_adaptive_speculation(6)
            .with_spec_ewma_alpha(0.5)
            .with_spec_demote_acceptance(0.1);
        assert_eq!(ad.spec.mode, SpecMode::Adaptive);
        assert!(ad.spec.enabled());
        assert_eq!(ad.spec.max_draft(), 6);
        assert!((ad.spec.ewma_alpha - 0.5).abs() < 1e-12);
        assert!((ad.spec.demote_acceptance - 0.1).abs() < 1e-12);
        // adaptive with k_max 0 is off; disable() kills either mode
        assert!(!cfg.clone().with_adaptive_speculation(0).spec.enabled());
        let mut s = ad.spec;
        s.disable();
        assert!(!s.enabled());
        assert_eq!(s.max_draft(), 0);
        // degenerate alpha clamped to something usable
        let c = EngineConfig::new("llama-7b-sim", COOPT).with_spec_ewma_alpha(0.0);
        assert!(c.spec.ewma_alpha > 0.0);
        // parse round-trips
        for m in [SpecMode::Fixed, SpecMode::Adaptive] {
            assert_eq!(SpecMode::parse(m.name()).unwrap(), m);
        }
        assert!(SpecMode::parse("bogus").is_err());
    }

    #[test]
    fn router_policy_knobs() {
        assert_eq!(RouterPolicy::default(), RouterPolicy::LeastLoaded);
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RouterPolicy::parse("bogus").is_err());
    }

    #[test]
    fn replica_role_knobs() {
        assert_eq!(ReplicaRole::default(), ReplicaRole::Mixed);
        for r in ReplicaRole::ALL {
            assert_eq!(ReplicaRole::parse(r.name()).unwrap(), r);
        }
        assert!(ReplicaRole::parse("bogus").is_err());
        // phase admission matrix
        assert!(ReplicaRole::Prefill.accepts_prefill());
        assert!(!ReplicaRole::Prefill.accepts_decode());
        assert!(!ReplicaRole::Decode.accepts_prefill());
        assert!(ReplicaRole::Decode.accepts_decode());
        assert!(ReplicaRole::Mixed.accepts_prefill());
        assert!(ReplicaRole::Mixed.accepts_decode());
        // engines default to mixed and opt in per-deployment
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert_eq!(cfg.role, ReplicaRole::Mixed);
        let cfg = cfg.with_role(ReplicaRole::Prefill);
        assert_eq!(cfg.role, ReplicaRole::Prefill);
        // role-list parsing for --replica-roles
        let roles = parse_replica_roles("prefill, decode,mixed").unwrap();
        assert_eq!(
            roles,
            vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed]
        );
        assert!(parse_replica_roles("").unwrap().is_empty());
        assert!(parse_replica_roles("prefill,bogus").is_err());
    }

    #[test]
    fn slo_knobs() {
        // off by default: untagged traffic is interactive and nothing
        // sheds, reserves, or cancels — the pre-SLO behaviour exactly
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        assert!(!cfg.slo.admission);
        assert_eq!(cfg.slo.interactive_ttft_ms, 250);
        assert!(cfg.slo.interactive_prefill_reserve.abs() < 1e-12);
        assert!((cfg.slo.tenant_share - 0.5).abs() < 1e-12);
        assert_eq!(cfg.slo.max_batch_queue, 16);
        let cfg = cfg
            .with_slo_admission(true)
            .with_interactive_ttft_ms(120)
            .with_interactive_prefill_reserve(0.4)
            .with_tenant_share(0.25)
            .with_max_batch_queue(4);
        assert!(cfg.slo.admission);
        assert_eq!(cfg.slo.interactive_ttft_ms, 120);
        assert!((cfg.slo.interactive_prefill_reserve - 0.4).abs() < 1e-12);
        assert!((cfg.slo.tenant_share - 0.25).abs() < 1e-12);
        assert_eq!(cfg.slo.max_batch_queue, 4);
        // degenerate values are clamped to something runnable
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_interactive_ttft_ms(0)
            .with_interactive_prefill_reserve(7.0)
            .with_tenant_share(0.0)
            .with_max_batch_queue(0);
        assert_eq!(cfg.slo.interactive_ttft_ms, 1);
        assert!((cfg.slo.interactive_prefill_reserve - 0.9).abs() < 1e-12);
        assert!((cfg.slo.tenant_share - 0.05).abs() < 1e-12);
        assert_eq!(cfg.slo.max_batch_queue, 1);
    }

    #[test]
    fn priority_class_knobs() {
        // untagged requests default to the protected class
        assert_eq!(Priority::default(), Priority::Interactive);
        assert!(Priority::Interactive.is_interactive());
        assert!(!Priority::Batch.is_interactive());
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("bogus").is_err());
        // ReqClass threads priority + deadline + tenant
        let c = ReqClass::default();
        assert_eq!(c.priority, Priority::Interactive);
        assert!(c.deadline_ms.is_none() && c.tenant.is_none());
        let c = ReqClass::batch().with_deadline_ms(500).with_tenant("t7");
        assert_eq!(c.priority, Priority::Batch);
        assert_eq!(c.deadline_ms, Some(500));
        assert_eq!(c.tenant.as_deref(), Some("t7"));
        assert_eq!(ReqClass::interactive().priority, Priority::Interactive);
    }

    #[test]
    fn geometry_context() {
        let g = CacheGeometry::default();
        assert_eq!(g.max_context(), 160);
    }

    #[test]
    fn manifest_parse_minimal() {
        let tmp = std::env::temp_dir().join(format!("coopt-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let manifest = r#"{
          "version": 1, "block_size": 16, "max_blocks": 10,
          "num_pool_blocks": 96, "max_batch": 8, "max_seq": 128,
          "models": {"m1": {
            "name": "m1", "stands_for": "X", "layers": 2, "d_model": 64,
            "n_heads": 2, "n_kv_heads_gqa": 1, "ffn": 128, "vocab": 260,
            "head_dim": 32, "paper_layers": 32, "paper_d_model": 4096,
            "paper_heads": 32, "block_size": 16, "max_blocks": 10,
            "num_pool_blocks": 96, "max_batch": 8, "max_seq": 128,
            "weights_file": "m1.weights.bin",
            "weights": [{"name": "embed", "offset": 0, "nbytes": 66560,
                         "shape": [260, 64]}]
          }},
          "graphs": [{
            "model": "m1", "config": "coopt", "phase": "decode",
            "file": "m1_coopt_decode.hlo.txt",
            "weights": ["embed"],
            "runtime_inputs": [{"name": "token_ids", "dtype": "i32", "shape": [8]}],
            "num_outputs": 5
          }],
          "eval_sets": {"easy": "arc_sim_easy.json"}
        }"#;
        std::fs::write(tmp.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&tmp).unwrap();
        assert_eq!(m.geometry.block_size, 16);
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.model("m1").unwrap().weights[0].shape, vec![260, 64]);
        assert_eq!(
            m.graph("m1", "coopt", "decode").unwrap().num_outputs,
            5
        );
        assert!(m.graph("m1", "coopt", "prefill").is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
