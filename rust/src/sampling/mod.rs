//! Token sampling and MCQ scoring over model logits.
//!
//! Greedy/temperature/top-k/top-p for generation; `mcq_scores` implements
//! the ARC single-token scoring protocol (§4.3.2: argmax over the choice
//! letters' next-token log-probs).  [`verify_token`] is the speculative
//! draft-and-verify acceptance rule: greedy token match, or standard
//! rejection sampling over the (target, draft) distribution pair, which
//! provably preserves the target distribution when drafts are samples of
//! the draft distribution.

use crate::config::SpecPolicy;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 = greedy
    pub temperature: f64,
    /// 0 = disabled
    pub top_k: usize,
    /// 1.0 = disabled
    pub top_p: f64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    // scale by temperature, softmax over the filtered candidate set
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    if params.top_k > 0 {
        idx.truncate(params.top_k.max(1));
    }
    let inv_t = 1.0 / params.temperature;
    let m = logits[idx[0]] as f64;
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - m) * inv_t).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    if params.top_p < 1.0 {
        // nucleus: keep the smallest prefix with cumulative mass >= top_p
        let mut cum = 0.0;
        let mut keep = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= params.top_p {
                keep = i + 1;
                break;
            }
        }
        probs.truncate(keep);
        idx.truncate(keep);
        let s: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= s;
        }
    }
    let mut target = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        target -= p;
        if target <= 0.0 {
            return idx[i] as u32;
        }
    }
    idx[probs.len() - 1] as u32
}

/// Outcome of verifying one speculative draft token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecDecision {
    /// the draft token is committed as-is
    Accept,
    /// the draft is rejected; the carried token is the correction the
    /// target model commits instead (speculation stops at this position)
    Reject(u32),
}

/// Verify one draft token against the target model's logits at the same
/// position.
///
/// Greedy rule (temperature <= 0, or the [`SpecPolicy::Greedy`]
/// deterministic-verification override): accept iff the draft equals the
/// target argmax, otherwise reject to the argmax — the exact token
/// sequential greedy decode would emit, so greedy speculation is
/// output-preserving by construction.
///
/// Stochastic rule ([`SpecPolicy::Stochastic`], the default, under
/// temperature sampling): standard speculative rejection sampling over
/// the *same filtered candidate set [`sample`] uses* (temperature
/// softmax after top-k, then nucleus truncation) — accept with
/// probability `min(1, p(d)/q(d))`; on rejection sample from the
/// residual `max(p - q, 0)` renormalized.  When the draft was sampled
/// from `q`, the committed token is distributed exactly as `sample`
/// would have drawn it (see the distribution-preservation tests below).
pub fn verify_token(
    draft: u32,
    target_logits: &[f32],
    draft_logits: &[f32],
    params: &SamplingParams,
    policy: SpecPolicy,
    rng: &mut Rng,
) -> SpecDecision {
    if params.temperature <= 0.0 || policy == SpecPolicy::Greedy {
        let best = argmax(target_logits) as u32;
        return if draft == best {
            SpecDecision::Accept
        } else {
            SpecDecision::Reject(best)
        };
    }
    let p = filtered_probs(target_logits, params);
    let q = filtered_probs(draft_logits, params);
    let d = draft as usize;
    // d ~ q in theory; guard the q(d)=0 corner so a token the target's
    // filtered set excludes can never be committed
    let accept_p = if q[d] > 0.0 {
        (p[d] / q[d]).min(1.0)
    } else if p[d] > 0.0 {
        1.0
    } else {
        0.0
    };
    if rng.f64() < accept_p {
        return SpecDecision::Accept;
    }
    // residual distribution: where the target puts mass the draft did not
    let mut resid: Vec<f64> = p.iter().zip(&q).map(|(&pi, &qi)| (pi - qi).max(0.0)).collect();
    let z: f64 = resid.iter().sum();
    if z <= 0.0 {
        // p == q everywhere; any target sample is a valid correction
        return SpecDecision::Reject(sample_from_probs(&p, rng));
    }
    for r in &mut resid {
        *r /= z;
    }
    SpecDecision::Reject(sample_from_probs(&resid, rng))
}

/// The probability distribution [`sample`] actually draws from:
/// temperature softmax over the top-k set, then nucleus (top-p)
/// truncation, renormalized and scattered back over the full vocabulary
/// (zero outside the kept set).  Mirrors `sample`'s filtering exactly so
/// speculative rejection sampling preserves its distribution, top-k and
/// top-p included.
fn filtered_probs(logits: &[f32], params: &SamplingParams) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    if params.top_k > 0 {
        idx.truncate(params.top_k.max(1));
    }
    let inv_t = 1.0 / params.temperature.max(1e-6);
    let m = logits[idx[0]] as f64;
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - m) * inv_t).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    if params.top_p < 1.0 {
        let mut cum = 0.0;
        let mut keep = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= params.top_p {
                keep = i + 1;
                break;
            }
        }
        probs.truncate(keep);
        idx.truncate(keep);
        let s: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= s;
        }
    }
    let mut full = vec![0.0f64; logits.len()];
    for (j, &i) in idx.iter().enumerate() {
        full[i] = probs[j];
    }
    full
}

fn sample_from_probs(probs: &[f64], rng: &mut Rng) -> u32 {
    let mut target = rng.f64();
    let mut last_nonzero = 0usize;
    for (i, &p) in probs.iter().enumerate() {
        if p > 0.0 {
            last_nonzero = i;
            target -= p;
            if target <= 0.0 {
                return i as u32;
            }
        }
    }
    // float-accumulation fallback: these arrays span the full vocabulary
    // with zeros outside the kept candidate set, so the fallback must be
    // a kept token, never a raw trailing index
    last_nonzero as u32
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Log-softmax value of token `id` under `logits`.
pub fn log_prob(logits: &[f32], id: u32) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (logits[id as usize] as f64 - m) - z.ln()
}

/// ARC/MMLU-style MCQ scoring: log-probs of each candidate token id at the
/// answer position.  Returns (best_choice_index, scores).
pub fn mcq_scores(logits: &[f32], choice_ids: &[u32]) -> (usize, Vec<f64>) {
    let scores: Vec<f64> = choice_ids.iter().map(|&c| log_prob(logits, c)).collect();
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate() {
        if s > scores[best] {
            best = i;
        }
    }
    (best, scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &SamplingParams::default(), &mut rng), 1);
    }

    #[test]
    fn temperature_samples_all_modes() {
        let logits = vec![1.0, 1.0, 1.0];
        let mut rng = Rng::new(1);
        let p = SamplingParams {
            temperature: 1.0,
            ..Default::default()
        };
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn top_k_restricts() {
        let logits = vec![5.0, 4.0, -10.0, -10.0];
        let mut rng = Rng::new(2);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
        };
        for _ in 0..100 {
            assert!(sample(&logits, &p, &mut rng) < 2);
        }
    }

    #[test]
    fn top_p_restricts() {
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(3);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
        };
        for _ in 0..100 {
            assert_eq!(sample(&logits, &p, &mut rng), 0);
        }
    }

    #[test]
    fn log_prob_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn greedy_verify_matches_sequential_greedy() {
        let mut rng = Rng::new(0);
        let mut target = vec![0.0f32; 8];
        target[3] = 5.0;
        let draft = vec![0.0f32; 8];
        let p = SamplingParams::default();
        assert_eq!(
            verify_token(3, &target, &draft, &p, SpecPolicy::Greedy, &mut rng),
            SpecDecision::Accept
        );
        assert_eq!(
            verify_token(5, &target, &draft, &p, SpecPolicy::Greedy, &mut rng),
            SpecDecision::Reject(3)
        );
        // temperature 0 forces the greedy rule even for Stochastic policy
        assert_eq!(
            verify_token(5, &target, &draft, &p, SpecPolicy::Stochastic, &mut rng),
            SpecDecision::Reject(3)
        );
    }

    /// The rejection-sampling guarantee: when drafts are drawn from the
    /// draft distribution q, the committed token (accepted draft or
    /// residual correction) is distributed exactly as the target p.
    #[test]
    fn stochastic_verify_preserves_target_distribution() {
        let target = vec![1.0f32, 0.0, 2.0, -1.0];
        let draft = vec![0.0f32, 1.5, 0.5, 0.0];
        let params = SamplingParams {
            temperature: 1.0,
            ..Default::default()
        };
        let p = filtered_probs(&target, &params);
        let q = filtered_probs(&draft, &params);
        let mut rng = Rng::new(42);
        let n = 100_000usize;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let d = sample_from_probs(&q, &mut rng);
            let committed = match verify_token(
                d,
                &target,
                &draft,
                &params,
                SpecPolicy::Stochastic,
                &mut rng,
            ) {
                SpecDecision::Accept => d,
                SpecDecision::Reject(c) => c,
            };
            counts[committed as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let freq = c as f64 / n as f64;
            assert!(
                (freq - p[i]).abs() < 0.01,
                "token {i}: observed {freq:.4} vs target {:.4}",
                p[i]
            );
        }
    }

    /// Same guarantee with top-k/top-p active: the filtered candidate
    /// set matches `sample`'s, so verification can never commit a token
    /// sequential sampling could not emit.
    #[test]
    fn stochastic_verify_respects_top_k_and_top_p() {
        let target = vec![3.0f32, 2.5, 2.0, -1.0, -2.0];
        let draft = vec![2.0f32, 3.0, 1.0, 4.0, -2.0];
        let params = SamplingParams {
            temperature: 1.0,
            top_k: 3,
            top_p: 0.95,
        };
        let p = filtered_probs(&target, &params);
        // the target's filtered set excludes tokens 3 and 4
        assert_eq!(p[3], 0.0);
        assert_eq!(p[4], 0.0);
        let q = filtered_probs(&draft, &params);
        let mut rng = Rng::new(7);
        let n = 50_000usize;
        let mut counts = [0usize; 5];
        for _ in 0..n {
            let d = sample_from_probs(&q, &mut rng);
            let committed = match verify_token(
                d,
                &target,
                &draft,
                &params,
                SpecPolicy::Stochastic,
                &mut rng,
            ) {
                SpecDecision::Accept => d,
                SpecDecision::Reject(c) => c,
            };
            counts[committed as usize] += 1;
        }
        assert_eq!(counts[3], 0, "token outside the target's top-k never commits");
        assert_eq!(counts[4], 0);
        for (i, &c) in counts.iter().enumerate().take(3) {
            let freq = c as f64 / n as f64;
            assert!(
                (freq - p[i]).abs() < 0.015,
                "token {i}: observed {freq:.4} vs target {:.4}",
                p[i]
            );
        }
    }

    #[test]
    fn mcq_picks_highest() {
        let mut logits = vec![0.0f32; 300];
        logits[65] = 1.0; // 'A'
        logits[66] = 3.0; // 'B'
        logits[67] = 2.0; // 'C'
        logits[68] = 0.5; // 'D'
        let (best, scores) = mcq_scores(&logits, &[65, 66, 67, 68]);
        assert_eq!(best, 1);
        assert_eq!(scores.len(), 4);
        assert!(scores[1] > scores[2]);
    }
}
