//! Token sampling and MCQ scoring over model logits.
//!
//! Greedy/temperature/top-k/top-p for generation; `mcq_scores` implements
//! the ARC single-token scoring protocol (§4.3.2: argmax over the choice
//! letters' next-token log-probs).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 = greedy
    pub temperature: f64,
    /// 0 = disabled
    pub top_k: usize,
    /// 1.0 = disabled
    pub top_p: f64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    // scale by temperature, softmax over the filtered candidate set
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    if params.top_k > 0 {
        idx.truncate(params.top_k.max(1));
    }
    let inv_t = 1.0 / params.temperature;
    let m = logits[idx[0]] as f64;
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - m) * inv_t).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    if params.top_p < 1.0 {
        // nucleus: keep the smallest prefix with cumulative mass >= top_p
        let mut cum = 0.0;
        let mut keep = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= params.top_p {
                keep = i + 1;
                break;
            }
        }
        probs.truncate(keep);
        idx.truncate(keep);
        let s: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= s;
        }
    }
    let mut target = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        target -= p;
        if target <= 0.0 {
            return idx[i] as u32;
        }
    }
    idx[probs.len() - 1] as u32
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Log-softmax value of token `id` under `logits`.
pub fn log_prob(logits: &[f32], id: u32) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (logits[id as usize] as f64 - m) - z.ln()
}

/// ARC/MMLU-style MCQ scoring: log-probs of each candidate token id at the
/// answer position.  Returns (best_choice_index, scores).
pub fn mcq_scores(logits: &[f32], choice_ids: &[u32]) -> (usize, Vec<f64>) {
    let scores: Vec<f64> = choice_ids.iter().map(|&c| log_prob(logits, c)).collect();
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate() {
        if s > scores[best] {
            best = i;
        }
    }
    (best, scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &SamplingParams::default(), &mut rng), 1);
    }

    #[test]
    fn temperature_samples_all_modes() {
        let logits = vec![1.0, 1.0, 1.0];
        let mut rng = Rng::new(1);
        let p = SamplingParams {
            temperature: 1.0,
            ..Default::default()
        };
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn top_k_restricts() {
        let logits = vec![5.0, 4.0, -10.0, -10.0];
        let mut rng = Rng::new(2);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
        };
        for _ in 0..100 {
            assert!(sample(&logits, &p, &mut rng) < 2);
        }
    }

    #[test]
    fn top_p_restricts() {
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(3);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
        };
        for _ in 0..100 {
            assert_eq!(sample(&logits, &p, &mut rng), 0);
        }
    }

    #[test]
    fn log_prob_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mcq_picks_highest() {
        let mut logits = vec![0.0f32; 300];
        logits[65] = 1.0; // 'A'
        logits[66] = 3.0; // 'B'
        logits[67] = 2.0; // 'C'
        logits[68] = 0.5; // 'D'
        let (best, scores) = mcq_scores(&logits, &[65, 66, 67, 68]);
        assert_eq!(best, 1);
        assert_eq!(scores.len(), 4);
        assert!(scores[1] > scores[2]);
    }
}
