//! PJRT runtime: load AOT artifacts and execute prefill/decode steps with
//! persistent device buffers.
//!
//! One [`ModelRuntime`] per (model, opt-config): it compiles the two HLO
//! graphs (`<model>_<cfg>_{prefill,decode}.hlo.txt`), uploads the weights
//! once, owns the paged KV pool as device buffers, and exposes
//! `prefill`/`decode` calls that the coordinator drives.  Python is never
//! involved: HLO **text** is parsed by the XLA runtime itself
//! (`HloModuleProto::from_text_file`), see DESIGN.md for why text.
//!
//! Output handling: the graphs are lowered with `return_tuple=True`.  Some
//! PJRT builds untuple the root automatically (N buffers per replica),
//! others return a single tuple buffer; [`ModelRuntime::execute`] detects
//! which at the first call and keeps cache outputs on-device in the
//! untupled case (the steady-state fast path — logits are the only
//! per-step host transfer).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{CacheGeometry, GraphEntry, Manifest, ModelEntry, ModelPreset, OptConfig};

pub mod mock;

/// What the coordinator needs from an execution backend (the PJRT runtime
/// in production, [`mock::MockBackend`] in engine unit tests).
pub trait Backend {
    fn preset(&self) -> &ModelPreset;
    fn geometry(&self) -> &CacheGeometry;
    fn opt(&self) -> &OptConfig;
    /// Prefill one sequence.  `token_ids`/`slot_mapping` are padded to
    /// max_seq.  Returns logits `[max_seq * vocab]` (row-major).
    fn prefill(&mut self, token_ids: &[i32], seq_len: i32, slot_mapping: &[i32])
        -> Result<Vec<f32>>;
    /// Chunked prefill (Opt-Pa step 1): process prompt positions
    /// `[offset, offset+chunk_len)` attending to all earlier KV.
    /// `token_ids` is the full padded prompt (real tokens in
    /// `0..offset+chunk_len`); `slot_mapping` carries writes only for the
    /// window (earlier positions are already resident and map to -1).
    /// Returns logits `[max_seq * vocab]`; only the row at
    /// `offset+chunk_len-1` is meaningful, and the engine samples it only
    /// on the final chunk.
    ///
    /// The default covers the window == whole-prompt case with the
    /// one-shot prefill graph and rejects true mid-prompt chunks — the
    /// AOT graph set is one-shot, so the PJRT runtime inherits this;
    /// the mock backend implements real chunk semantics for the engine
    /// suite.
    fn prefill_chunk(
        &mut self,
        token_ids: &[i32],
        offset: i32,
        chunk_len: i32,
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        if offset == 0 {
            return self.prefill(token_ids, chunk_len, slot_mapping);
        }
        bail!(
            "backend does not support chunked prefill (chunk at offset {offset}); \
             lower a chunked prefill graph or disable chunked_prefill"
        )
    }
    /// Whether [`Backend::prefill_chunk`] handles mid-prompt windows
    /// (`offset > 0`).  The engine consults this at construction and
    /// falls back to one-shot scheduling when false, so a chunked config
    /// can never wedge a backend whose graphs are one-shot.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }
    /// Opt-KV tier manager: copy one KV block device -> host (slot ids
    /// come from the cache's [`crate::kvcache::tier::HostPool`]).  The
    /// engine calls this immediately after the cache releases the device
    /// block, before anything can recycle it.
    ///
    /// The default rejects: the AOT graph set has no host staging
    /// buffers, so the PJRT runtime inherits this and the engine degrades
    /// to drop-and-recompute preemption (no engine ever wedges on a
    /// backend without swap support).  The mock implements real copy
    /// semantics with a swap trace.
    fn swap_out(&mut self, device_block: u32, host_slot: u64) -> Result<()> {
        bail!(
            "backend does not support KV swap (block {device_block} -> host slot {host_slot}); \
             preemption must drop and recompute"
        )
    }
    /// Opt-KV tier manager: copy one KV block host -> device.  Must be
    /// executed before the owning sequence is stepped again.
    fn swap_in(&mut self, host_slot: u64, device_block: u32) -> Result<()> {
        bail!(
            "backend does not support KV swap (host slot {host_slot} -> block {device_block}); \
             preemption must drop and recompute"
        )
    }
    /// Opt-KV tier manager: a swapped-out block's host copy was abandoned
    /// (drop-to-recompute fallback) — release its staging buffer.  Host
    /// slot ids are never reused, so skipping this leaks host memory on a
    /// real backend.  Default no-op (backends without swap never see one).
    fn swap_discard(&mut self, _host_slot: u64) -> Result<()> {
        Ok(())
    }
    /// Whether [`Backend::swap_out`]/[`Backend::swap_in`] move real KV
    /// bytes.  The engine consults this at construction and disables the
    /// host tier when false.
    fn supports_kv_swap(&self) -> bool {
        false
    }
    /// PD disaggregation: export one KV block's payload through a host
    /// staging slot for a cross-replica hand-off.  Unlike
    /// [`Backend::swap_out`] this *copies* — the returned opaque payload
    /// travels in the hand-off envelope while the host slot is released
    /// right after (the slot is staging, not residence).  The engine
    /// calls this before anything can recycle the freed device block.
    ///
    /// The default rejects, so backends without migration support make
    /// the router fall back to token-level hand-off (the destination
    /// re-prefills); no engine ever wedges on it.
    fn export_block(&mut self, device_block: u32, host_slot: u64) -> Result<u64> {
        bail!(
            "backend does not support KV migration (export block {device_block} \
             via host slot {host_slot}); hand-off must fall back to re-prefill"
        )
    }
    /// PD disaggregation: import one exported KV payload into a freshly
    /// allocated device block on the destination replica.  Must be
    /// executed before the migrated sequence is stepped.
    fn import_block(&mut self, device_block: u32, payload: u64) -> Result<()> {
        bail!(
            "backend does not support KV migration (import payload {payload} \
             into block {device_block}); hand-off must fall back to re-prefill"
        )
    }
    /// Whether [`Backend::export_block`]/[`Backend::import_block`] move
    /// real KV bytes.  Consulted per hand-off; when false the router's
    /// PD path transfers tokens only and the destination re-prefills.
    fn supports_kv_migration(&self) -> bool {
        false
    }
    /// Cluster prefix reuse: export the KV payload already resident in a
    /// *host* slot, without disturbing it.  Unlike [`Backend::export_block`]
    /// (which stages a device block through a scratch host slot) the block
    /// here lives in the host tier and stays there — the copy feeds a
    /// cross-replica prefix pull while the owning sequence can still swap
    /// the block back in later.  Gated by
    /// [`Backend::supports_kv_migration`]; the default rejects so pulls
    /// fall back to re-prefill on backends without the transport.
    fn export_host_block(&mut self, host_slot: u64) -> Result<u64> {
        bail!(
            "backend does not support KV migration (export host slot \
             {host_slot}); prefix pull must fall back to re-prefill"
        )
    }
    /// Speculative decoding: propose `k` draft tokens per active lane
    /// with a shrunk draft model.  Inputs are padded to max_batch as in
    /// [`Backend::decode`]; `ctx_lens[lane]` counts the fed token and
    /// `positions[lane] == ctx_lens[lane] - 1`.  Returns `(tokens,
    /// logits)`: tokens `[max_batch * k]` — the draft chain's proposals
    /// (-1 on inactive lanes) — and logits `[max_batch * k * vocab]`, the
    /// draft distribution each proposal was taken from (the `q` of
    /// standard speculative rejection sampling).
    ///
    /// **Contract:** each proposal must actually be distributed according
    /// to its returned logits row — rejection sampling preserves the
    /// target distribution only under `d ~ q`.  A *deterministic* draft
    /// chain therefore must report (near-)one-hot logits for its choice,
    /// which makes `q` a point mass and the acceptance rule collapse to
    /// "accept with probability p(d)" — still exactly
    /// distribution-preserving.  The mock's greedy chain does this (its
    /// rows put ~all mass on the proposed token); a backend that samples
    /// its drafts must return the distribution it sampled from.
    ///
    /// `k` is a *per-round* argument, not a construction-time constant:
    /// the adaptive speculation controller legitimately changes it
    /// between rounds (including k = 0 rounds that skip draft/verify
    /// entirely), and a backend must not cache it.  Within one round the
    /// paired [`Backend::verify`] call always scores the same `k`
    /// positions (the mock enforces this pairing).
    ///
    /// The default rejects: the AOT graph set has no draft model, so the
    /// PJRT runtime inherits this and engines degrade to one-token decode
    /// via [`Backend::supports_speculation`].  The mock implements a
    /// deterministic draft chain that deliberately disagrees with the
    /// target now and then, so the rejection/rollback path is exercised.
    fn draft(
        &mut self,
        _token_ids: &[i32],
        _positions: &[i32],
        _ctx_lens: &[i32],
        k: usize,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        bail!(
            "backend does not support speculative drafting (k={k}); \
             disable speculation or lower a draft graph"
        )
    }

    /// Speculative decoding: score `k + 1` positions per lane in ONE
    /// target-model pass — the amortization speculation buys: the whole
    /// KV cache is re-read once for up to k+1 token commits instead of
    /// once per token.  `token_ids`/`slot_mapping` are
    /// `[max_batch * (k+1)]`: each lane row holds the last committed
    /// token followed by its k draft proposals, with the KV write slot of
    /// each position; `positions[lane]` is the first fed position and
    /// `ctx_lens[lane]` the context *including* all k+1 writes.  Returns
    /// logits `[max_batch * (k+1) * vocab]`, where row `(lane, i)` is the
    /// target distribution for the token following fed token `i`.  The
    /// engine rolls rejected suffix positions back through
    /// [`crate::kvcache::CacheManager::truncate_seq`].
    #[allow(clippy::too_many_arguments)]
    fn verify(
        &mut self,
        _token_ids: &[i32],
        _positions: &[i32],
        _block_tables: &[i32],
        _ctx_lens: &[i32],
        _slot_mapping: &[i32],
        k: usize,
    ) -> Result<Vec<f32>> {
        bail!(
            "backend does not support speculative verification (k={k}); \
             disable speculation or lower a multi-token scoring graph"
        )
    }

    /// Whether [`Backend::draft`]/[`Backend::verify`] are implemented.
    /// The engine consults this at construction and falls back to
    /// one-token decode when false, so a speculative config can never
    /// wedge a backend whose graphs score one position per pass.
    fn supports_speculation(&self) -> bool {
        false
    }

    /// Batched decode step; all arrays padded to max_batch.  Returns
    /// logits `[max_batch * vocab]`.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        ctx_lens: &[i32],
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>>;
    /// Zero the KV pool (new serving session).
    fn reset_cache(&mut self) -> Result<()>;
    /// Wallclock spent inside execute calls since the last call to this.
    fn take_exec_time(&mut self) -> Duration;
}

/// Shared PJRT client (one per process).
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(into_anyhow)?;
        Ok(Runtime { client, manifest })
    }

    pub fn load_model(&self, model: &str, opt: OptConfig) -> Result<ModelRuntime> {
        ModelRuntime::load(self, model, opt)
    }
}

struct CacheBuffers {
    /// k_cache, v_cache [, k_scale, v_scale]
    bufs: Vec<PjRtBuffer>,
}

/// Compiled + resident model for one opt-config.
pub struct ModelRuntime {
    client: PjRtClient,
    preset: ModelPreset,
    geometry: CacheGeometry,
    opt: OptConfig,
    prefill_exe: PjRtLoadedExecutable,
    decode_exe: PjRtLoadedExecutable,
    prefill_graph: GraphEntry,
    decode_graph: GraphEntry,
    /// all checkpoint weights, uploaded once
    weight_bufs: Vec<(String, PjRtBuffer)>,
    /// per-phase positional indices into `weight_bufs` (graphs reference a
    /// subset of the checkpoint; XLA DCEs the rest)
    prefill_weight_idx: Vec<usize>,
    decode_weight_idx: Vec<usize>,
    cache: CacheBuffers,
    /// cache tensor shapes/dtypes (from the manifest, positional)
    cache_specs: Vec<(Vec<usize>, String)>,
    untupled: Option<bool>,
    exec_time: Duration,
    pub compile_time: Duration,
}

// SAFETY: `ModelRuntime` is only ever *moved* into a single engine thread
// (EngineHandle::spawn) and used by one thread at a time thereafter.  The
// !Send inference comes from raw pointers inside the xla crate's wrappers,
// not from thread-local state; the PJRT CPU client has no thread affinity.
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    pub fn load(rt: &Runtime, model: &str, opt: OptConfig) -> Result<Self> {
        let m: &ModelEntry = rt.manifest.model(model)?;
        let prefill_graph = rt.manifest.graph(model, opt.name, "prefill")?.clone();
        let decode_graph = rt.manifest.graph(model, opt.name, "decode")?.clone();

        let t0 = Instant::now();
        let prefill_exe = compile(&rt.client, &rt.manifest.dir.join(&prefill_graph.file))?;
        let decode_exe = compile(&rt.client, &rt.manifest.dir.join(&decode_graph.file))?;
        let compile_time = t0.elapsed();

        // upload weights once (persistent device buffers)
        let wpath = rt.manifest.dir.join(&m.weights_file);
        let raw = std::fs::read(&wpath)
            .with_context(|| format!("reading weights {}", wpath.display()))?;
        let mut weight_bufs = Vec::with_capacity(m.weights.len());
        for w in &m.weights {
            let bytes = raw
                .get(w.offset..w.offset + w.nbytes)
                .ok_or_else(|| anyhow!("weights file too short for '{}'", w.name))?;
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = rt
                .client
                .buffer_from_host_buffer(&floats, &w.shape, None)
                .map_err(into_anyhow)?;
            weight_bufs.push((w.name.clone(), buf));
        }
        let index_of = |name: &str| -> Result<usize> {
            weight_bufs
                .iter()
                .position(|(n, _)| n == name)
                .ok_or_else(|| anyhow!("graph references unknown weight '{name}'"))
        };
        let prefill_weight_idx = prefill_graph
            .weights
            .iter()
            .map(|n| index_of(n))
            .collect::<Result<Vec<_>>>()?;
        let decode_weight_idx = decode_graph
            .weights
            .iter()
            .map(|n| index_of(n))
            .collect::<Result<Vec<_>>>()?;

        // cache tensors come after the scalar runtime inputs in both graphs;
        // identify them by name prefix
        let cache_specs: Vec<(Vec<usize>, String)> = decode_graph
            .runtime_inputs
            .iter()
            .filter(|t| t.name.ends_with("cache") || t.name.ends_with("scale"))
            .map(|t| (t.shape.clone(), t.dtype.clone()))
            .collect();

        let mut s = ModelRuntime {
            client: rt.client.clone(),
            preset: m.preset.clone(),
            geometry: rt.manifest.geometry,
            opt,
            prefill_exe,
            decode_exe,
            prefill_graph,
            decode_graph,
            weight_bufs,
            prefill_weight_idx,
            decode_weight_idx,
            cache: CacheBuffers { bufs: Vec::new() },
            cache_specs,
            untupled: None,
            exec_time: Duration::ZERO,
            compile_time,
        };
        s.reset_cache()?;
        Ok(s)
    }

    pub fn opt_name(&self) -> &'static str {
        self.opt.name
    }

    fn zero_cache_buffers(&self) -> Result<Vec<PjRtBuffer>> {
        self.cache_specs
            .iter()
            .map(|(shape, dtype)| {
                let n: usize = shape.iter().product();
                match dtype.as_str() {
                    // NOTE: use the typed path — the crate's
                    // buffer_from_host_raw_bytes passes `ElementType as i32`
                    // (positional discriminant) where PJRT expects
                    // PrimitiveType ids, mislabeling U8 buffers as S64.
                    "u8" => self
                        .client
                        .buffer_from_host_buffer(&vec![0u8; n], shape, None)
                        .map_err(into_anyhow),
                    "f32" => self
                        .client
                        .buffer_from_host_buffer(&vec![0f32; n], shape, None)
                        .map_err(into_anyhow),
                    other => bail!("unsupported cache dtype {other}"),
                }
            })
            .collect()
    }

    fn i32_buf(&self, data: &[i32], shape: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(into_anyhow)
    }

    /// Run one executable with (weights ++ runtime inputs ++ caches),
    /// replace the cache buffers from the outputs, return the logits.
    fn execute(
        &mut self,
        which: Phase,
        runtime_bufs: Vec<PjRtBuffer>,
        logits_len: usize,
    ) -> Result<Vec<f32>> {
        let n_cache = self.cache.bufs.len();
        let (n_outputs, weight_idx) = match which {
            Phase::Prefill => (self.prefill_graph.num_outputs, &self.prefill_weight_idx),
            Phase::Decode => (self.decode_graph.num_outputs, &self.decode_weight_idx),
        };
        debug_assert_eq!(n_outputs, 1 + n_cache);

        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(weight_idx.len() + runtime_bufs.len() + n_cache);
        args.extend(weight_idx.iter().map(|&i| &self.weight_bufs[i].1));
        args.extend(runtime_bufs.iter());
        args.extend(self.cache.bufs.iter());

        let exe = match which {
            Phase::Prefill => &self.prefill_exe,
            Phase::Decode => &self.decode_exe,
        };
        let t0 = Instant::now();
        let mut out = exe.execute_b(&args).map_err(into_anyhow)?;
        let replica = out
            .get_mut(0)
            .and_then(|r| if r.is_empty() { None } else { Some(r) })
            .ok_or_else(|| anyhow!("execute produced no outputs"))?;

        let untupled = *self.untupled.get_or_insert(replica.len() == n_outputs);
        let logits = if untupled {
            // fast path: logits to host, caches stay on device
            let mut bufs = std::mem::take(replica);
            if bufs.len() != n_outputs {
                bail!("expected {n_outputs} outputs, got {}", bufs.len());
            }
            let logits_buf = bufs.remove(0);
            self.cache.bufs = bufs;
            let lit = logits_buf.to_literal_sync().map_err(into_anyhow)?;
            lit.to_vec::<f32>().map_err(into_anyhow)?
        } else {
            // tuple path: pull the tuple to host, re-upload the caches
            let lit = replica[0].to_literal_sync().map_err(into_anyhow)?;
            let mut parts = lit.to_tuple().map_err(into_anyhow)?;
            if parts.len() != n_outputs {
                bail!("expected {n_outputs} tuple parts, got {}", parts.len());
            }
            let logits_lit = parts.remove(0);
            let mut cache_bufs = Vec::with_capacity(parts.len());
            for (p, (shape, dtype)) in parts.into_iter().zip(&self.cache_specs) {
                // NOTE: upload via the typed host-buffer path
                // (kImmutableOnlyDuringCall — synchronous copy).  The
                // crate's buffer_from_host_literal uses BufferFromHostLiteral
                // whose copy is asynchronous; dropping the literal before the
                // transfer completes is a use-after-free (observed SIGSEGV in
                // AbstractTfrtCpuBuffer::CopyFromLiteral).
                let buf = match dtype.as_str() {
                    "u8" => {
                        let v = p.to_vec::<u8>().map_err(into_anyhow)?;
                        self.client
                            .buffer_from_host_buffer(&v, shape, None)
                            .map_err(into_anyhow)?
                    }
                    "f32" => {
                        let v = p.to_vec::<f32>().map_err(into_anyhow)?;
                        self.client
                            .buffer_from_host_buffer(&v, shape, None)
                            .map_err(into_anyhow)?
                    }
                    other => bail!("unsupported cache dtype {other}"),
                };
                cache_bufs.push(buf);
            }
            self.cache.bufs = cache_bufs;
            logits_lit.to_vec::<f32>().map_err(into_anyhow)?
        };
        self.exec_time += t0.elapsed();

        if logits.len() != logits_len {
            bail!("logits length {} != expected {logits_len}", logits.len());
        }
        Ok(logits)
    }
}

#[derive(Clone, Copy)]
enum Phase {
    Prefill,
    Decode,
}

impl Backend for ModelRuntime {
    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn geometry(&self) -> &CacheGeometry {
        &self.geometry
    }

    fn opt(&self) -> &OptConfig {
        &self.opt
    }

    fn prefill(
        &mut self,
        token_ids: &[i32],
        seq_len: i32,
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        let s = self.geometry.max_seq;
        if token_ids.len() != s || slot_mapping.len() != s {
            bail!("prefill inputs must be padded to max_seq {s}");
        }
        let bufs = vec![
            self.i32_buf(token_ids, &[s])?,
            self.i32_buf(&[seq_len], &[1])?,
            self.i32_buf(slot_mapping, &[s])?,
        ];
        self.execute(Phase::Prefill, bufs, s * self.preset.vocab)
    }

    fn decode(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        ctx_lens: &[i32],
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        let b = self.geometry.max_batch;
        let mb = self.geometry.max_blocks;
        if token_ids.len() != b
            || positions.len() != b
            || ctx_lens.len() != b
            || slot_mapping.len() != b
            || block_tables.len() != b * mb
        {
            bail!("decode inputs must be padded to max_batch {b} x max_blocks {mb}");
        }
        let bufs = vec![
            self.i32_buf(token_ids, &[b])?,
            self.i32_buf(positions, &[b])?,
            self.i32_buf(block_tables, &[b, mb])?,
            self.i32_buf(ctx_lens, &[b])?,
            self.i32_buf(slot_mapping, &[b])?,
        ];
        self.execute(Phase::Decode, bufs, b * self.preset.vocab)
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.cache.bufs = self.zero_cache_buffers()?;
        Ok(())
    }

    fn take_exec_time(&mut self) -> Duration {
        std::mem::take(&mut self.exec_time)
    }
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow!("non-UTF-8 artifact path"))?,
    )
    .map_err(into_anyhow)
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(into_anyhow)
}

fn into_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("XLA: {e}")
}

/// Convenience for tests: does an artifacts dir with a manifest exist?
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").exists()
}
