//! Deterministic mock backend for engine/scheduler tests and L3 micro-
//! benchmarks (no artifacts or PJRT involved).
//!
//! Logits are a pure function of (last token, position, lane) so tests can
//! assert exact decode behaviour; an optional per-call delay emulates
//! kernel time for scheduling experiments.  The mock also *verifies* the
//! coordinator's invariants on every call (padding discipline, slot/ctx
//! consistency), turning every engine test into a contract check.
//!
//! **KV swap (Opt-KV tier manager)**: the mock implements real copy
//! semantics over per-block payload stamps.  Every KV write marks its
//! block device-resident; [`MockBackend::swap_out`] moves the payload to
//! a host store keyed by slot and [`MockBackend::swap_in`] moves it back,
//! with every transfer recorded in `swap_trace`.  The decode contract
//! then checks *residency*: stepping a sequence whose block was swapped
//! out (and never swapped back) fails loudly instead of silently reading
//! stale KV — the exact bug class a tiered engine can introduce.

use std::collections::{HashMap, HashSet};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::{builtin_preset, CacheGeometry, ModelPreset, OptConfig, COOPT};

use super::Backend;

pub struct MockBackend {
    preset: ModelPreset,
    geometry: CacheGeometry,
    opt: OptConfig,
    pub delay: Duration,
    pub prefill_calls: usize,
    pub decode_calls: usize,
    exec_time: Duration,
    /// emitted token for lane b at step s = (seed + b + s*7) % 200 + 32
    pub seed: u32,
    /// record of every (ctx_lens, slot_mapping) decode saw, for tests
    pub decode_trace: Vec<(Vec<i32>, Vec<i32>)>,
    /// record of every prefill window as (offset, chunk_len), for tests
    /// (one-shot prefill records (0, seq_len))
    pub chunk_trace: Vec<(i32, i32)>,
    /// device-resident KV payload stamps, one per written block
    device_payload: HashMap<u32, u64>,
    /// host-tier payload stamps, keyed by host slot
    host_payload: HashMap<u64, u64>,
    /// record of every swap as ('O'|'I', device block, host slot)
    pub swap_trace: Vec<(char, u32, u64)>,
    /// speculative decoding: every verify pass as (active lanes, k)
    pub spec_trace: Vec<(usize, usize)>,
    /// the draft length of every draft call, in order — adaptive
    /// speculation legitimately varies k between rounds, and tests
    /// assert the trace shows it
    pub draft_k_trace: Vec<usize>,
    /// k of the most recent draft not yet consumed by a verify: the
    /// verify of the same round must score the same k positions
    pending_draft_k: Option<usize>,
    pub draft_calls: usize,
    pub verify_calls: usize,
    /// the draft chain disagrees with the target whenever
    /// `(seed + last) % draft_divergence == 0` (0 = a perfect draft),
    /// so rejection sampling and KV rollback are actually exercised
    pub draft_divergence: u64,
    stamp: u64,
}

impl MockBackend {
    pub fn new() -> Self {
        Self::with_geometry(CacheGeometry::default())
    }

    pub fn with_geometry(geometry: CacheGeometry) -> Self {
        MockBackend {
            preset: builtin_preset("llama-7b-sim").unwrap(),
            geometry,
            opt: COOPT,
            delay: Duration::ZERO,
            prefill_calls: 0,
            decode_calls: 0,
            exec_time: Duration::ZERO,
            seed: 0,
            decode_trace: Vec::new(),
            chunk_trace: Vec::new(),
            device_payload: HashMap::new(),
            host_payload: HashMap::new(),
            swap_trace: Vec::new(),
            spec_trace: Vec::new(),
            draft_k_trace: Vec::new(),
            pending_draft_k: None,
            draft_calls: 0,
            verify_calls: 0,
            draft_divergence: 5,
            stamp: 0,
        }
    }

    pub fn with_opt(mut self, opt: OptConfig) -> Self {
        self.opt = opt;
        self
    }

    /// Mark the block behind every written slot device-resident.
    fn stamp_writes(&mut self, slot_mapping: &[i32]) {
        let bs = self.geometry.block_size;
        for &sl in slot_mapping {
            if sl >= 0 {
                self.stamp += 1;
                self.device_payload.insert((sl as usize / bs) as u32, self.stamp);
            }
        }
    }

    fn spin(&mut self) {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.exec_time += self.delay;
    }

    fn logits_for(&self, favored: u32, vocab: usize) -> Vec<f32> {
        let mut row = vec![0.0f32; vocab];
        row[(favored as usize) % vocab] = 10.0;
        row
    }

    /// The decode-path target function: the token the target model favors
    /// after `last` (shared by `decode` and `verify` so greedy
    /// speculation is provably output-preserving against sequential
    /// decode).
    fn target_favored(&self, last: u32) -> u32 {
        32 + (self.seed + last + 7) % 200
    }

    /// The draft model's proposal after `last`: agrees with the target
    /// except at the configured divergence points.
    fn draft_favored(&self, last: u32) -> u32 {
        if self.draft_divergence > 0
            && (self.seed as u64 + last as u64) % self.draft_divergence == 0
        {
            // always differs from target_favored (offset 84 mod 200)
            32 + (self.seed + last + 91) % 200
        } else {
            self.target_favored(last)
        }
    }
}

impl Default for MockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MockBackend {
    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn geometry(&self) -> &CacheGeometry {
        &self.geometry
    }

    fn opt(&self) -> &OptConfig {
        &self.opt
    }

    fn prefill(
        &mut self,
        token_ids: &[i32],
        seq_len: i32,
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        let s = self.geometry.max_seq;
        if token_ids.len() != s || slot_mapping.len() != s {
            bail!("mock: prefill inputs not padded to max_seq");
        }
        if seq_len <= 0 || seq_len as usize > s {
            bail!("mock: bad seq_len {seq_len}");
        }
        // contract: real prompt positions hold real tokens
        for (i, &t) in token_ids.iter().enumerate().take(seq_len as usize) {
            if t < 0 {
                bail!("mock: negative token at prompt position {i}");
            }
        }
        self.prefill_calls += 1;
        self.chunk_trace.push((0, seq_len));
        self.stamp_writes(slot_mapping);
        self.spin();
        let vocab = self.preset.vocab;
        let mut logits = vec![0.0f32; s * vocab];
        // the next token depends deterministically on the last prompt token
        let last = token_ids[seq_len as usize - 1] as u32;
        let favored = 32 + (self.seed + last) % 200;
        let row = self.logits_for(favored, vocab);
        let at = (seq_len as usize - 1) * vocab;
        logits[at..at + vocab].copy_from_slice(&row);
        Ok(logits)
    }

    fn prefill_chunk(
        &mut self,
        token_ids: &[i32],
        offset: i32,
        chunk_len: i32,
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        let s = self.geometry.max_seq;
        if token_ids.len() != s || slot_mapping.len() != s {
            bail!("mock: chunk inputs not padded to max_seq");
        }
        if offset < 0 || chunk_len <= 0 {
            bail!("mock: bad chunk window [{offset}, {})", offset + chunk_len);
        }
        let end = (offset + chunk_len) as usize;
        if end > s {
            bail!("mock: chunk end {end} exceeds max_seq {s}");
        }
        // contract: everything up to the window's end is a real token
        for (i, &t) in token_ids.iter().enumerate().take(end) {
            if t < 0 {
                bail!("mock: negative token at position {i} of a chunk ending at {end}");
            }
        }
        // contract: earlier chunks already wrote their slots — a window
        // must never re-write positions before its offset
        for (i, &m) in slot_mapping.iter().enumerate().take(offset as usize) {
            if m != -1 {
                bail!("mock: chunk at offset {offset} re-writes earlier slot at position {i}");
            }
        }
        self.prefill_calls += 1;
        self.chunk_trace.push((offset, chunk_len));
        self.stamp_writes(slot_mapping);
        self.spin();
        let vocab = self.preset.vocab;
        let mut logits = vec![0.0f32; s * vocab];
        // identical function of the last visible token as one-shot
        // prefill, so chunked and one-shot greedy decoding agree exactly
        let last = token_ids[end - 1] as u32;
        let favored = 32 + (self.seed + last) % 200;
        let row = self.logits_for(favored, vocab);
        let at = (end - 1) * vocab;
        logits[at..at + vocab].copy_from_slice(&row);
        Ok(logits)
    }

    fn decode(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        ctx_lens: &[i32],
        slot_mapping: &[i32],
    ) -> Result<Vec<f32>> {
        let b = self.geometry.max_batch;
        let mb = self.geometry.max_blocks;
        if token_ids.len() != b
            || positions.len() != b
            || ctx_lens.len() != b
            || slot_mapping.len() != b
            || block_tables.len() != b * mb
        {
            bail!("mock: decode inputs not padded");
        }
        // contract checks the real runtime silently relies on
        for lane in 0..b {
            let ctx = ctx_lens[lane];
            if ctx == 0 {
                if slot_mapping[lane] != -1 {
                    bail!("mock: inactive lane {lane} has a write slot");
                }
                continue;
            }
            if positions[lane] != ctx - 1 {
                bail!(
                    "mock: lane {lane} position {} != ctx-1 {}",
                    positions[lane],
                    ctx - 1
                );
            }
            if slot_mapping[lane] < 0 {
                bail!("mock: active lane {lane} lost its write slot");
            }
            let blocks_needed = (ctx as usize).div_ceil(self.geometry.block_size);
            if blocks_needed > mb {
                bail!("mock: lane {lane} ctx {ctx} overflows the block table");
            }
        }
        // this step's writes land first (a fresh tail block is written by
        // this very call), then residency is enforced: every block the
        // kernel would traverse must hold device-resident payload — a
        // swapped-out block that was never swapped back fails here
        for lane in 0..b {
            if ctx_lens[lane] > 0 {
                self.stamp += 1;
                let blk = (slot_mapping[lane] as usize / self.geometry.block_size) as u32;
                self.device_payload.insert(blk, self.stamp);
            }
        }
        for lane in 0..b {
            let ctx = ctx_lens[lane];
            if ctx == 0 {
                continue;
            }
            let valid = (ctx as usize).div_ceil(self.geometry.block_size);
            for j in 0..valid {
                let blk = block_tables[lane * mb + j];
                if blk < 0 || !self.device_payload.contains_key(&(blk as u32)) {
                    bail!(
                        "mock: lane {lane} reads block {blk} (logical {j}) that is not \
                         device-resident — swapped out without swap-in?"
                    );
                }
            }
        }
        self.decode_calls += 1;
        self.decode_trace
            .push((ctx_lens.to_vec(), slot_mapping.to_vec()));
        self.spin();
        let vocab = self.preset.vocab;
        let mut logits = vec![0.0f32; b * vocab];
        for lane in 0..b {
            if ctx_lens[lane] == 0 {
                continue;
            }
            let favored = self.target_favored(token_ids[lane] as u32);
            let row = self.logits_for(favored, vocab);
            logits[lane * vocab..(lane + 1) * vocab].copy_from_slice(&row);
        }
        Ok(logits)
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn draft(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        ctx_lens: &[i32],
        k: usize,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let b = self.geometry.max_batch;
        if token_ids.len() != b || positions.len() != b || ctx_lens.len() != b {
            bail!("mock: draft inputs not padded to max_batch");
        }
        if k == 0 {
            bail!("mock: draft of zero tokens");
        }
        let vocab = self.preset.vocab;
        let mut toks = vec![-1i32; b * k];
        let mut logits = vec![0.0f32; b * k * vocab];
        for lane in 0..b {
            let ctx = ctx_lens[lane];
            if ctx == 0 {
                continue;
            }
            if positions[lane] != ctx - 1 {
                bail!(
                    "mock: draft lane {lane} position {} != ctx-1 {}",
                    positions[lane],
                    ctx - 1
                );
            }
            if token_ids[lane] < 0 {
                bail!("mock: draft lane {lane} fed a padding token");
            }
            // greedy draft chain: each proposal conditions on the previous
            let mut last = token_ids[lane] as u32;
            for i in 0..k {
                let favored = self.draft_favored(last);
                let row = self.logits_for(favored, vocab);
                logits[(lane * k + i) * vocab..(lane * k + i + 1) * vocab]
                    .copy_from_slice(&row);
                toks[lane * k + i] = favored as i32;
                last = favored;
            }
        }
        self.draft_calls += 1;
        self.draft_k_trace.push(k);
        // the k of a round is free to differ from the previous round's
        // (adaptive speculation) but the round's own verify must match
        self.pending_draft_k = Some(k);
        self.spin();
        Ok((toks, logits))
    }

    fn verify(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        ctx_lens: &[i32],
        slot_mapping: &[i32],
        k: usize,
    ) -> Result<Vec<f32>> {
        let b = self.geometry.max_batch;
        let mb = self.geometry.max_blocks;
        let bs = self.geometry.block_size;
        let n = k + 1;
        if token_ids.len() != b * n
            || positions.len() != b
            || ctx_lens.len() != b
            || slot_mapping.len() != b * n
            || block_tables.len() != b * mb
        {
            bail!("mock: verify inputs not padded to max_batch x (k+1)");
        }
        // contract: a verify scores exactly the positions its round
        // drafted — k may change between rounds, never inside one
        if let Some(dk) = self.pending_draft_k.take() {
            if dk != k {
                bail!("mock: verify k={k} does not match the round's drafted k={dk}");
            }
        }
        // contract checks the real runtime silently relies on
        let mut seen_slots: HashSet<i32> = HashSet::new();
        for lane in 0..b {
            let ctx = ctx_lens[lane];
            if ctx == 0 {
                for i in 0..n {
                    if slot_mapping[lane * n + i] != -1 {
                        bail!("mock: inactive verify lane {lane} has a write slot");
                    }
                }
                continue;
            }
            if positions[lane] + n as i32 != ctx {
                bail!(
                    "mock: verify lane {lane} spans [{}, {}) but ctx is {ctx}",
                    positions[lane],
                    positions[lane] + n as i32
                );
            }
            if (ctx as usize).div_ceil(bs) > mb {
                bail!("mock: verify lane {lane} ctx {ctx} overflows the block table");
            }
            for i in 0..n {
                if token_ids[lane * n + i] < 0 {
                    bail!("mock: verify lane {lane} fed a padding token at position {i}");
                }
                let sl = slot_mapping[lane * n + i];
                if sl < 0 {
                    bail!("mock: verify lane {lane} lost its write slot at position {i}");
                }
                if !seen_slots.insert(sl) {
                    bail!("mock: verify slot {sl} written twice in one pass");
                }
            }
        }
        // this pass's k+1 writes per lane land first, then residency is
        // enforced over every block the kernel would traverse (a
        // rolled-back block that was recycled without a rewrite, or a
        // swapped-out block, fails here)
        for lane in 0..b {
            if ctx_lens[lane] == 0 {
                continue;
            }
            for i in 0..n {
                self.stamp += 1;
                let blk = (slot_mapping[lane * n + i] as usize / bs) as u32;
                self.device_payload.insert(blk, self.stamp);
            }
        }
        for lane in 0..b {
            let ctx = ctx_lens[lane];
            if ctx == 0 {
                continue;
            }
            let valid = (ctx as usize).div_ceil(bs);
            for j in 0..valid {
                let blk = block_tables[lane * mb + j];
                if blk < 0 || !self.device_payload.contains_key(&(blk as u32)) {
                    bail!(
                        "mock: verify lane {lane} reads block {blk} (logical {j}) that is \
                         not device-resident"
                    );
                }
            }
        }
        self.verify_calls += 1;
        self.spec_trace
            .push((ctx_lens.iter().filter(|&&c| c > 0).count(), k));
        self.spin();
        let vocab = self.preset.vocab;
        let mut logits = vec![0.0f32; b * n * vocab];
        for lane in 0..b {
            if ctx_lens[lane] == 0 {
                continue;
            }
            for i in 0..n {
                // row i = the target distribution for the token following
                // fed token i — the same function `decode` applies, so a
                // verify pass scores exactly what k+1 sequential decode
                // steps would have
                let favored = self.target_favored(token_ids[lane * n + i] as u32);
                let row = self.logits_for(favored, vocab);
                logits[(lane * n + i) * vocab..(lane * n + i + 1) * vocab]
                    .copy_from_slice(&row);
            }
        }
        Ok(logits)
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    fn swap_out(&mut self, device_block: u32, host_slot: u64) -> Result<()> {
        if self.host_payload.contains_key(&host_slot) {
            bail!("mock: swap_out into occupied host slot {host_slot}");
        }
        let Some(payload) = self.device_payload.remove(&device_block) else {
            bail!(
                "mock: swap_out of block {device_block} that holds no device payload \
                 (never written, or already swapped out)"
            );
        };
        self.host_payload.insert(host_slot, payload);
        self.swap_trace.push(('O', device_block, host_slot));
        self.spin();
        Ok(())
    }

    fn swap_in(&mut self, host_slot: u64, device_block: u32) -> Result<()> {
        let Some(payload) = self.host_payload.remove(&host_slot) else {
            bail!("mock: swap_in from empty host slot {host_slot}");
        };
        self.device_payload.insert(device_block, payload);
        self.swap_trace.push(('I', device_block, host_slot));
        self.spin();
        Ok(())
    }

    fn swap_discard(&mut self, host_slot: u64) -> Result<()> {
        if self.host_payload.remove(&host_slot).is_none() {
            bail!("mock: swap_discard of empty host slot {host_slot}");
        }
        self.swap_trace.push(('D', 0, host_slot));
        Ok(())
    }

    fn supports_kv_swap(&self) -> bool {
        true
    }

    fn export_block(&mut self, device_block: u32, host_slot: u64) -> Result<u64> {
        if self.host_payload.contains_key(&host_slot) {
            bail!("mock: export_block into occupied host slot {host_slot}");
        }
        // a migration export COPIES: the device payload stays resident
        // until the cache actually frees the block, and the returned
        // payload is what travels in the hand-off envelope
        let Some(&payload) = self.device_payload.get(&device_block) else {
            bail!(
                "mock: export_block of block {device_block} that holds no device \
                 payload (never written, or already swapped out)"
            );
        };
        self.host_payload.insert(host_slot, payload);
        self.swap_trace.push(('E', device_block, host_slot));
        self.spin();
        Ok(payload)
    }

    fn import_block(&mut self, device_block: u32, payload: u64) -> Result<()> {
        self.device_payload.insert(device_block, payload);
        self.swap_trace.push(('M', device_block, payload));
        self.spin();
        Ok(())
    }

    fn supports_kv_migration(&self) -> bool {
        true
    }

    fn export_host_block(&mut self, host_slot: u64) -> Result<u64> {
        // a prefix-pull export of host-resident KV is non-destructive:
        // the slot keeps its payload (the owning sequence may swap it
        // back in); only a copy travels in the pull envelope
        let Some(&payload) = self.host_payload.get(&host_slot) else {
            bail!(
                "mock: export_host_block of slot {host_slot} that holds no \
                 payload (never swapped out, or already discarded)"
            );
        };
        self.swap_trace.push(('H', 0, host_slot));
        self.spin();
        Ok(payload)
    }

    fn reset_cache(&mut self) -> Result<()> {
        self.device_payload.clear();
        self.host_payload.clear();
        self.pending_draft_k = None;
        Ok(())
    }

    fn take_exec_time(&mut self) -> Duration {
        std::mem::take(&mut self.exec_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_contract() {
        let mut m = MockBackend::new();
        let s = m.geometry().max_seq;
        let mut toks = vec![0i32; s];
        toks[0] = 65;
        let slots = vec![-1i32; s];
        assert!(m.prefill(&toks, 1, &slots).is_ok());
        assert!(m.prefill(&toks, 0, &slots).is_err());
        assert!(m.prefill(&toks[1..], 1, &slots).is_err());
        assert_eq!(m.prefill_calls, 1);
    }

    #[test]
    fn decode_contract_catches_bad_lanes() {
        let mut m = MockBackend::new();
        let g = *m.geometry();
        let b = g.max_batch;
        let mut ctx = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut slots = vec![-1i32; b];
        let toks = vec![1i32; b];
        let bt = vec![0i32; b * g.max_blocks];
        // one active lane, consistent
        ctx[0] = 5;
        pos[0] = 4;
        slots[0] = 4;
        assert!(m.decode(&toks, &pos, &bt, &ctx, &slots).is_ok());
        // inconsistent position
        pos[0] = 3;
        assert!(m.decode(&toks, &pos, &bt, &ctx, &slots).is_err());
        pos[0] = 4;
        // inactive lane with a slot
        slots[1] = 3;
        assert!(m.decode(&toks, &pos, &bt, &ctx, &slots).is_err());
    }

    #[test]
    fn chunk_contract_and_equivalence() {
        let mut m = MockBackend::new();
        let s = m.geometry().max_seq;
        let mut toks = vec![0i32; s];
        for (i, t) in toks.iter_mut().enumerate().take(12) {
            *t = 40 + i as i32;
        }
        let mut slots = vec![-1i32; s];
        for (i, sl) in slots.iter_mut().enumerate().take(12) {
            *sl = i as i32;
        }
        // one-shot row at position 11
        let one = m.prefill(&toks, 12, &slots).unwrap();
        // the same prompt as a mid-prompt chunk [8, 12): final row agrees
        let mut chunk_slots = vec![-1i32; s];
        for (i, sl) in chunk_slots.iter_mut().enumerate().take(12).skip(8) {
            *sl = i as i32;
        }
        let two = m.prefill_chunk(&toks, 8, 4, &chunk_slots).unwrap();
        let vocab = m.preset().vocab;
        assert_eq!(one[11 * vocab..12 * vocab], two[11 * vocab..12 * vocab]);
        assert_eq!(m.chunk_trace, vec![(0, 12), (8, 4)]);
        // contract violations
        assert!(m.prefill_chunk(&toks, 8, 0, &chunk_slots).is_err(), "empty window");
        assert!(m.prefill_chunk(&toks, 8, 4, &slots).is_err(), "re-writes earlier slots");
        assert!(
            m.prefill_chunk(&toks, (s - 2) as i32, 4, &chunk_slots).is_err(),
            "window past max_seq"
        );
    }

    #[test]
    fn swap_copy_semantics_and_residency_contract() {
        let mut m = MockBackend::with_geometry(CacheGeometry {
            block_size: 4,
            max_blocks: 4,
            num_pool_blocks: 8,
            max_batch: 2,
            max_seq: 16,
        });
        let s = m.geometry().max_seq;
        // prefill 8 tokens into blocks 0 and 1
        let mut toks = vec![0i32; s];
        let mut slots = vec![-1i32; s];
        for i in 0..8 {
            toks[i] = 40 + i as i32;
            slots[i] = i as i32;
        }
        m.prefill(&toks, 8, &slots).unwrap();

        // decode over both blocks works while resident
        let g = *m.geometry();
        let mut ctx = vec![0i32; g.max_batch];
        let mut pos = vec![0i32; g.max_batch];
        let mut sm = vec![-1i32; g.max_batch];
        let tid = vec![1i32; g.max_batch];
        let mut bt = vec![0i32; g.max_batch * g.max_blocks];
        bt[0] = 0;
        bt[1] = 1;
        bt[2] = 2;
        ctx[0] = 9;
        pos[0] = 8;
        sm[0] = 8; // writes block 2
        assert!(m.decode(&tid, &pos, &bt, &ctx, &sm).is_ok());

        // swap block 1 out: decoding over it must now fail loudly
        m.swap_out(1, 7).unwrap();
        assert!(
            m.decode(&tid, &pos, &bt, &ctx, &sm).is_err(),
            "decode over a swapped-out block must be rejected"
        );
        // double swap-out and empty-slot swap-in rejected
        assert!(m.swap_out(1, 8).is_err());
        assert!(m.swap_in(9, 1).is_err());
        // occupied host slot rejected (block 0 is still resident)
        assert!(m.swap_out(0, 7).is_err());

        // swap back in (into a different device block): decode resumes
        m.swap_in(7, 1).unwrap();
        assert!(m.decode(&tid, &pos, &bt, &ctx, &sm).is_ok());
        assert_eq!(m.swap_trace, vec![('O', 1, 7), ('I', 1, 7)]);
        assert!(m.supports_kv_swap());
    }

    #[test]
    fn export_copies_and_import_restores_residency() {
        let mut src = MockBackend::with_geometry(CacheGeometry {
            block_size: 4,
            max_blocks: 4,
            num_pool_blocks: 8,
            max_batch: 2,
            max_seq: 16,
        });
        let s = src.geometry().max_seq;
        let mut toks = vec![0i32; s];
        let mut slots = vec![-1i32; s];
        for i in 0..8 {
            toks[i] = 40 + i as i32;
            slots[i] = i as i32;
        }
        src.prefill(&toks, 8, &slots).unwrap();

        // export copies: the source block stays device-resident
        let p0 = src.export_block(0, 3).unwrap();
        let p1 = src.export_block(1, 4).unwrap();
        let g = *src.geometry();
        let mut ctx = vec![0i32; g.max_batch];
        let mut pos = vec![0i32; g.max_batch];
        let mut sm = vec![-1i32; g.max_batch];
        let tid = vec![1i32; g.max_batch];
        let mut bt = vec![0i32; g.max_batch * g.max_blocks];
        bt[1] = 1;
        bt[2] = 2;
        ctx[0] = 9;
        pos[0] = 8;
        sm[0] = 8;
        assert!(
            src.decode(&tid, &pos, &bt, &ctx, &sm).is_ok(),
            "export must not evict the source copy"
        );
        // staging slots behave like swap slots: occupied is rejected,
        // discard releases them
        assert!(src.export_block(2, 3).is_err(), "occupied staging slot");
        assert!(src.export_block(9, 5).is_err(), "unwritten block");
        src.swap_discard(3).unwrap();
        src.swap_discard(4).unwrap();

        // a second backend imports the payloads and can decode over them
        let mut dst = MockBackend::with_geometry(g);
        dst.import_block(0, p0).unwrap();
        dst.import_block(1, p1).unwrap();
        let mut dctx = vec![0i32; g.max_batch];
        let mut dpos = vec![0i32; g.max_batch];
        let mut dsm = vec![-1i32; g.max_batch];
        dctx[0] = 9;
        dpos[0] = 8;
        dsm[0] = 8;
        assert!(dst.decode(&tid, &dpos, &bt, &dctx, &dsm).is_ok());
        assert!(dst.supports_kv_migration());
        assert_eq!(dst.swap_trace, vec![('M', 0, p0), ('M', 1, p1)]);
    }

    #[test]
    fn draft_chain_is_deterministic_and_sometimes_diverges() {
        let mut m = MockBackend::new();
        let g = *m.geometry();
        let b = g.max_batch;
        let mut ctx = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut toks = vec![-1i32; b];
        ctx[0] = 6;
        pos[0] = 5;
        toks[0] = 50;
        let (t1, l1) = m.draft(&toks, &pos, &ctx, 4).unwrap();
        let (t2, l2) = m.draft(&toks, &pos, &ctx, 4).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(l1, l2);
        assert_eq!(m.draft_calls, 2);
        // inactive lanes propose nothing
        assert!(t1[4..].iter().all(|&t| t == -1));
        // the proposals are the draft chain over the draft function
        let mut last = 50u32;
        for i in 0..4 {
            let expect = m.draft_favored(last);
            assert_eq!(t1[i] as u32, expect);
            assert_eq!(
                crate::sampling::argmax(&l1[i * m.preset().vocab..(i + 1) * m.preset().vocab]),
                expect as usize
            );
            last = expect;
        }
        // over the token range, the draft must both agree and disagree
        // with the target somewhere (otherwise rejection is never hit)
        let (mut agree, mut differ) = (false, false);
        for t in 32..232u32 {
            if m.draft_favored(t) == m.target_favored(t) {
                agree = true;
            } else {
                differ = true;
            }
        }
        assert!(agree && differ);
        // contract violations
        pos[0] = 4;
        assert!(m.draft(&toks, &pos, &ctx, 4).is_err(), "position/ctx mismatch");
        pos[0] = 5;
        assert!(m.draft(&toks, &pos, &ctx, 0).is_err(), "zero draft length");
    }

    #[test]
    fn verify_scores_k_plus_one_positions_like_sequential_decode() {
        let mut m = MockBackend::with_geometry(CacheGeometry {
            block_size: 4,
            max_blocks: 4,
            num_pool_blocks: 8,
            max_batch: 2,
            max_seq: 16,
        });
        let g = *m.geometry();
        let (b, mb) = (g.max_batch, g.max_blocks);
        // prefill 5 tokens into blocks 0..2 so the context is resident
        let s = g.max_seq;
        let mut ptoks = vec![0i32; s];
        let mut pslots = vec![-1i32; s];
        for i in 0..5 {
            ptoks[i] = 40 + i as i32;
            pslots[i] = i as i32;
        }
        m.prefill(&ptoks, 5, &pslots).unwrap();

        let k = 2usize;
        let n = k + 1;
        // fed tokens [44, 60, 61] at positions 5..8 (ctx 8 after writes)
        let mut toks = vec![-1i32; b * n];
        toks[0] = 44;
        toks[1] = 60;
        toks[2] = 61;
        let mut pos = vec![0i32; b];
        pos[0] = 5;
        let mut ctx = vec![0i32; b];
        ctx[0] = 8;
        let mut slots = vec![-1i32; b * n];
        slots[0] = 5;
        slots[1] = 6;
        slots[2] = 7;
        let mut bt = vec![0i32; b * mb];
        bt[0] = 0;
        bt[1] = 1;
        let logits = m.verify(&toks, &pos, &bt, &ctx, &slots, k).unwrap();
        let vocab = m.preset().vocab;
        // each row equals the decode function of its fed token
        for (i, &t) in [44u32, 60, 61].iter().enumerate() {
            assert_eq!(
                crate::sampling::argmax(&logits[i * vocab..(i + 1) * vocab]),
                m.target_favored(t) as usize
            );
        }
        assert_eq!(m.spec_trace, vec![(1, 2)]);
        // contract violations: duplicate slot, lost slot, bad span
        let mut dup = slots.clone();
        dup[2] = 6;
        assert!(m.verify(&toks, &pos, &bt, &ctx, &dup, k).is_err());
        let mut lost = slots.clone();
        lost[1] = -1;
        assert!(m.verify(&toks, &pos, &bt, &ctx, &lost, k).is_err());
        let mut bad_ctx = ctx.clone();
        bad_ctx[0] = 9;
        assert!(m.verify(&toks, &pos, &bt, &bad_ctx, &slots, k).is_err());
        // a swapped-out block under the context fails residency
        m.swap_out(0, 7).unwrap();
        assert!(m.verify(&toks, &pos, &bt, &ctx, &slots, k).is_err());
        assert!(m.supports_speculation());
    }

    #[test]
    fn draft_verify_k_may_change_between_rounds_but_not_inside_one() {
        let mut m = MockBackend::with_geometry(CacheGeometry {
            block_size: 4,
            max_blocks: 4,
            num_pool_blocks: 8,
            max_batch: 2,
            max_seq: 16,
        });
        let g = *m.geometry();
        let (b, mb) = (g.max_batch, g.max_blocks);
        let s = g.max_seq;
        // make blocks 0..2 resident
        let mut ptoks = vec![0i32; s];
        let mut pslots = vec![-1i32; s];
        for i in 0..5 {
            ptoks[i] = 40 + i as i32;
            pslots[i] = i as i32;
        }
        m.prefill(&ptoks, 5, &pslots).unwrap();
        let mut pos = vec![0i32; b];
        pos[0] = 5;
        let mut dctx = vec![0i32; b];
        dctx[0] = 6;
        let mut dtoks = vec![-1i32; b];
        dtoks[0] = 44;
        let verify_inputs = |k: usize| {
            let n = k + 1;
            let mut toks = vec![-1i32; b * n];
            let mut slots = vec![-1i32; b * n];
            for i in 0..n {
                toks[i] = 44 + i as i32;
                slots[i] = 5 + i as i32;
            }
            let mut ctx = vec![0i32; b];
            ctx[0] = (6 + k) as i32;
            let mut bt = vec![0i32; b * mb];
            bt[0] = 0;
            bt[1] = 1;
            bt[2] = 2;
            (toks, slots, ctx, bt)
        };
        // round 1 at k=2: verify with a different k is a contract error
        m.draft(&dtoks, &pos, &dctx, 2).unwrap();
        let (t, sl, ctx, bt) = verify_inputs(1);
        assert!(
            m.verify(&t, &pos, &bt, &ctx, &sl, 1).is_err(),
            "verify k=1 after draft k=2 must be rejected"
        );
        // the failed verify consumed the pending draft; a fresh round at
        // a *different* k is legal — adaptive speculation in action
        m.draft(&dtoks, &pos, &dctx, 1).unwrap();
        let (t, sl, ctx, bt) = verify_inputs(1);
        m.verify(&t, &pos, &bt, &ctx, &sl, 1).unwrap();
        m.draft(&dtoks, &pos, &dctx, 3).unwrap();
        let (t, sl, ctx, bt) = verify_inputs(3);
        m.verify(&t, &pos, &bt, &ctx, &sl, 3).unwrap();
        assert_eq!(m.draft_k_trace, vec![2, 1, 3], "the k trace shows the variation");
    }

    #[test]
    fn deterministic_logits() {
        let mut m = MockBackend::new();
        let g = *m.geometry();
        let b = g.max_batch;
        let mut ctx = vec![0i32; b];
        ctx[0] = 3;
        let mut pos = vec![0i32; b];
        pos[0] = 2;
        let mut slots = vec![-1i32; b];
        slots[0] = 2;
        let toks = vec![42i32; b];
        let bt = vec![0i32; b * g.max_blocks];
        let l1 = m.decode(&toks, &pos, &bt, &ctx, &slots).unwrap();
        let l2 = m.decode(&toks, &pos, &bt, &ctx, &slots).unwrap();
        assert_eq!(l1, l2);
        let best = crate::sampling::argmax(&l1[..m.preset().vocab]);
        assert_eq!(best, 32 + (42 + 7) % 200);
    }
}
