//! Property + acceptance tests for the predictive telemetry plane.
//!
//! The contract under test: **forecasts steer control only while they
//! are earning their keep — and steering never changes outputs.**
//! Estimator state, however poisoned, may decide whether/when a request
//! runs (admission, routing, victim choice), never what it generates;
//! and an estimator whose calibration leaves the coverage band stops
//! being consumed at all — every control decision falls back to the
//! reactive path exactly, not to some degraded middle ground.

use llm_coopt::config::{EngineConfig, ForecastConfig, ReqClass, RouterPolicy, SloConfig, COOPT};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::obs::forecast::ForecastPlane;
use llm_coopt::router::{
    request_cost_estimate, request_cost_estimate_hinted, tightened_slo, Router, SHED_MARKER,
};
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::util::quickprop::{check, gens};

fn mock_engine() -> Engine<MockBackend> {
    Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    )
}

fn forecast_engine() -> Engine<MockBackend> {
    Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT)
            .with_forecast(true)
            .with_forecast_warmup(1),
    )
}

/// Feed a plane scored garbage: every length prediction misses by a
/// mile (p90 = 0 under an absurd actual drives coverage to zero and
/// floods the window with junk lengths), every wait quote under-predicts
/// catastrophically.  Both estimators leave the coverage band low; the
/// junk windows are what a consumer would read if band-gating ever
/// leaked.
fn poison_plane(p: &mut ForecastPlane) {
    for t in [None, Some("t0"), Some("t1"), Some("t2")] {
        for k in 0..8u32 {
            p.resolve_len(t, 1e9, 0.0, 40_000 + k);
        }
    }
    for _ in 0..8 {
        p.resolve_wait(0.0, 1.0, 1e9);
    }
}

/// Tenant-tagged class mix without deadlines: every admitted request
/// must finish normally, so token identity is strict equality.
fn class_for(p: usize, i: usize) -> ReqClass {
    match (p + i) % 4 {
        0 => ReqClass::interactive(),
        1 => ReqClass::batch().with_tenant(format!("t{}", p % 3)),
        2 => ReqClass::interactive().with_tenant(format!("t{}", p % 3)),
        _ => ReqClass::batch(),
    }
}

/// Property: 80 random paced traces through forecast-enabled routers
/// (varying policy, replica count, queue bound, pacing), with the
/// router plane and every engine plane poisoned before the run and the
/// router plane re-poisoned mid-stream.  Whatever the estimators
/// believe, per case:
///
/// (a) every admitted request is token-identical (tokens *and* finish
///     reason) to an unconstrained single-engine reference;
/// (b) offered = completed + shed, shed requests never complete, no
///     result arrives twice;
/// (c) after the run every replica's device pool and host tier drain
///     to zero — forecast-steered scheduling leaks nothing.
#[test]
fn poisoned_forecasts_never_change_outputs() {
    check(
        80,
        gens::pair(gens::vec(gens::usize_to(23), 3..=10), gens::usize_to(1000)),
        |&(ref profile, seed): &(Vec<usize>, usize)| {
            let n = profile.len();
            // the index rides in the correlation id: shed requests never
            // produce a result, so positional alignment cannot work
            let plain: Vec<GenRequest> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| {
                    let tenant = p % 3;
                    let mut req = GenRequest::greedy(
                        format!(
                            "tenantfc{tenant} {} tail {seed} {i} {}",
                            "s".repeat(18 + 2 * tenant),
                            "y".repeat(p)
                        ),
                        2 + (p + seed) % 6,
                    );
                    req.corr_id = Some(format!("fc/{i}"));
                    req
                })
                .collect();
            let classes: Vec<ReqClass> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| class_for(p, i))
                .collect();
            // token-identity reference: one unconstrained engine, untagged
            let mut single = mock_engine();
            let base = single.generate(plain.clone()).unwrap();

            let slo = SloConfig {
                admission: seed % 2 == 0,
                // generous budget: sheds ride the queue bound and tenant
                // share, which are pure functions of sim-side state
                interactive_ttft_ms: 50_000,
                interactive_prefill_reserve: 0.0,
                tenant_share: 0.6,
                max_batch_queue: 2 + seed % 4,
            };
            let policy = RouterPolicy::ALL[seed % RouterPolicy::ALL.len()];
            let nrep = 1 + (seed / 7) % 2;
            let steps_per_arrival = (seed / 3) % 3;

            let engines: Vec<Engine<MockBackend>> = (0..nrep)
                .map(|_| {
                    let mut e = forecast_engine();
                    poison_plane(e.forecast_plane_mut());
                    e
                })
                .collect();
            let mut router = Router::new(engines, policy)
                .with_slo(slo)
                .with_forecast(ForecastConfig {
                    enabled: true,
                    warmup: 1,
                    ..ForecastConfig::default()
                });
            poison_plane(router.forecast_mut());
            let mut shed = vec![false; n];
            for (i, req) in plain.iter().enumerate() {
                if i % 5 == 0 {
                    // keep re-poisoning: calibration must not be able to
                    // "recover" into trusting garbage windows
                    poison_plane(router.forecast_mut());
                }
                match router.submit(req.clone().with_class(classes[i].clone())) {
                    Ok((replica, _)) => {
                        if replica >= nrep {
                            return false;
                        }
                    }
                    Err(e) if e.to_string().starts_with(SHED_MARKER) => {
                        shed[i] = true;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                for _ in 0..steps_per_arrival {
                    router.step_all().unwrap();
                }
            }
            let results = router.run_to_completion().unwrap();
            // (b) conservation: offered = completed + shed
            if results.len() + shed.iter().filter(|&&s| s).count() != n {
                return false;
            }
            let mut seen = vec![false; n];
            for r in &results {
                let idx = r
                    .result
                    .corr_id
                    .as_deref()
                    .and_then(|c| c.strip_prefix("fc/"))
                    .and_then(|s| s.parse::<usize>().ok())
                    .expect("result lost its fc/<i> correlation id");
                if shed[idx] || seen[idx] {
                    return false; // shed requests never complete; no dups
                }
                seen[idx] = true;
                // (a) identity: forecasting may not change a single token
                if r.result.tokens != base[idx].tokens
                    || r.result.finish != base[idx].finish
                {
                    return false;
                }
            }
            if router.shed_requests() != shed.iter().filter(|&&s| s).count() as u64 {
                return false;
            }
            // (c) nothing leaked: device pool and host tier drain to zero
            router.replicas().iter().all(|e| {
                e.cache_stats().blocks_used == 0
                    && e.tier_stats().host_used_blocks == 0
            })
        },
    );
}

/// Acceptance: an estimator whose coverage leaves the band keeps
/// stamping (self-scoring must continue or calibration could never
/// recover) but stops being consumed — every consumer-facing getter
/// degrades to the reactive value exactly.
#[test]
fn out_of_band_estimators_fall_back_to_reactive_values() {
    let mut plane = ForecastPlane::new(ForecastConfig {
        enabled: true,
        warmup: 4,
        ..ForecastConfig::default()
    });
    for _ in 0..32 {
        plane.observe_arrival(Some("t0"));
        plane.tick(3, 2, 64, 8, 10);
    }
    // every length prediction misses: coverage 0, far below the band
    for k in 0..12u32 {
        plane.resolve_len(Some("t0"), 4.0, 0.0, 10 + k);
    }
    assert!(
        plane.len_quantiles(Some("t0")).is_some(),
        "raw stamps must keep flowing while out of band"
    );
    assert!(!plane.len_in_band(Some("t0")), "coverage 0 cannot be in band");
    assert_eq!(
        plane.len_hint_p90(Some("t0")),
        None,
        "out-of-band estimator leaked a consumable hint"
    );
    // the reactive fallback is exact, not approximate
    assert_eq!(
        request_cost_estimate_hinted(80, 32, None),
        request_cost_estimate(80, 32)
    );
    // every wait quote under-predicted catastrophically: coverage 0
    for _ in 0..12 {
        plane.resolve_wait(0.0, 1.0, 1e9);
    }
    assert!(plane.wait_resolved() >= 12);
    assert!(!plane.wait_in_band());
    assert_eq!(plane.wait_ms_per_load(), None, "learned drain rate leaked");
    assert_eq!(plane.predict_wait_ms(5.0), None);
    assert!(
        plane.wait_quote_ms(5.0).is_some(),
        "scoring quotes must survive the band exit"
    );
    // no scored burst: admission knobs must pass through untouched
    assert_eq!(plane.admission_tighten(), 1.0);
    let slo = SloConfig {
        admission: true,
        max_batch_queue: 7,
        ..SloConfig::default()
    };
    assert_eq!(tightened_slo(&slo, plane.admission_tighten()), slo);
    assert_eq!(plane.effective_watermark(3), 3);
}

/// Acceptance: a forecast-enabled router whose estimators can *never*
/// enter the band (warm-up beyond any run, burst ratio beyond any
/// arrival pattern) reproduces the reactive router bit for bit on an
/// overloaded paced trace — the same requests shed, the same results in
/// the same order, the same tokens.  Stamping and scoring alone must
/// cost nothing behavioral.
#[test]
fn never_in_band_forecasting_is_bit_identical_to_reactive() {
    let n = 24;
    let plain: Vec<GenRequest> = (0..n)
        .map(|i| {
            let tenant = i % 3;
            let mut req = GenRequest::greedy(
                format!("tenantnb{tenant} {} tail {i}", "s".repeat(16 + 2 * tenant)),
                3 + i % 5,
            );
            req.corr_id = Some(format!("nb/{i}"));
            req
        })
        .collect();
    let classes: Vec<ReqClass> = (0..n).map(|i| class_for(i % 7, i)).collect();
    let slo = SloConfig {
        admission: true,
        interactive_ttft_ms: 50_000,
        interactive_prefill_reserve: 0.5,
        tenant_share: 0.6,
        max_batch_queue: 2,
    };

    let run = |forecast: bool| {
        let engines: Vec<Engine<MockBackend>> = (0..2)
            .map(|_| {
                let cfg = EngineConfig::new("llama-7b-sim", COOPT);
                let cfg = if forecast {
                    cfg.with_forecast(true)
                        .with_forecast_warmup(u64::MAX)
                        .with_forecast_burst_ratio(1e18)
                } else {
                    cfg
                };
                Engine::new(MockBackend::new().with_opt(COOPT), cfg)
            })
            .collect();
        let mut router = Router::new(engines, RouterPolicy::LeastLoaded).with_slo(slo);
        if forecast {
            router = router.with_forecast(ForecastConfig {
                enabled: true,
                warmup: u64::MAX,
                burst_ratio: 1e18,
                ..ForecastConfig::default()
            });
        }
        let mut shed = Vec::new();
        for (i, req) in plain.iter().enumerate() {
            match router.submit(req.clone().with_class(classes[i].clone())) {
                Ok(_) => {}
                Err(e) if e.to_string().starts_with(SHED_MARKER) => shed.push(i),
                Err(e) => panic!("unexpected submit error: {e}"),
            }
            router.step_all().unwrap();
        }
        let results: Vec<(String, Vec<u32>)> = router
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.result.corr_id.clone().unwrap(), r.result.tokens))
            .collect();
        (shed, results)
    };

    let (shed_fc, results_fc) = run(true);
    let (shed_off, results_off) = run(false);
    assert_eq!(shed_fc, shed_off, "out-of-band forecasting changed admission");
    assert_eq!(
        results_fc, results_off,
        "out-of-band forecasting changed the served schedule or its outputs"
    );
}
