//! Property + integration tests for the two-tier KV memory hierarchy
//! (Opt-KV tier manager): swap preemption must be *semantically
//! invisible* — with a host tier enabled and a device pool sized to force
//! preemption, greedy outputs are token-for-token identical to an
//! unconstrained run — and prefix-hash sharing must stay correct across
//! tiers.  The mock backend enforces copy semantics (residency contract)
//! on every decode, so each case doubles as a swap-correctness check.

use std::cell::Cell;

use llm_coopt::config::{CacheGeometry, EngineConfig, SwapPolicy, COOPT};
use llm_coopt::coordinator::Engine;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::sampling::SamplingParams;
use llm_coopt::util::quickprop::{check, gens};
use llm_coopt::util::rng::Rng;
use llm_coopt::workload::harness::run_swap_compare;

fn geometry(pool_blocks: usize) -> CacheGeometry {
    CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: pool_blocks,
        max_batch: 4,
        max_seq: 48,
    }
}

fn engine(pool_blocks: usize, host_blocks: usize, policy: SwapPolicy) -> Engine<MockBackend> {
    let be = MockBackend::with_geometry(geometry(pool_blocks)).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(host_blocks)
        .with_swap_policy(policy);
    Engine::new(be, cfg)
}

/// Acceptance: ≥ 100 random workloads, device pool undersized to force
/// preemption, host tier on — greedy outputs match the unconstrained run
/// token for token (swap is semantically invisible), the pool and host
/// tier drain to zero, and the suite as a whole actually exercised swap.
#[test]
fn swap_is_semantically_invisible_over_random_workloads() {
    let total_swaps = Cell::new(0u64);
    let total_preempts = Cell::new(0u64);
    check(
        120,
        gens::vec(gens::usize_to(30), 1..=8),
        |profile: &Vec<usize>| {
            // half the cases run the cost-based policy, half force swap
            let policy = if profile.len() % 2 == 0 {
                SwapPolicy::Always
            } else {
                SwapPolicy::Auto
            };
            let mut rng = Rng::new(profile.iter().sum::<usize>() as u64 ^ 0x5AB);
            let reqs: Vec<(Vec<u32>, usize)> = profile
                .iter()
                .map(|&p| {
                    let len = 1 + p; // 1..=31 prompt tokens
                    let toks: Vec<u32> = (0..len).map(|_| 33 + rng.below(200) as u32).collect();
                    (toks, 2 + p % 9)
                })
                .collect();

            let run = |mut e: Engine<MockBackend>| {
                for (toks, max_new) in &reqs {
                    e.submit_tokens(toks.clone(), *max_new, SamplingParams::default(), false)
                        .unwrap();
                }
                let mut r = match e.run_to_completion() {
                    Ok(r) => r,
                    Err(_) => return None,
                };
                r.sort_by_key(|x| x.id);
                Some((
                    r.into_iter()
                        .map(|x| (x.tokens, x.finish))
                        .collect::<Vec<_>>(),
                    e,
                ))
            };
            // unconstrained reference: big pool, single tier
            let Some((expected, base)) = run(engine(96, 0, SwapPolicy::Never)) else {
                return false;
            };
            if base.metrics.preemptions != 0 {
                return false; // reference must be genuinely unconstrained
            }
            // tiered run: pool sized to force preemption.  The host tier
            // is sized for the worst case (8 seqs x 11 blocks) so no
            // preemption is ever forced onto the recompute fallback —
            // recompute re-samples a decoded tail token through the
            // prefill function, which the mock deliberately distinguishes
            // from decode; exact-equality is the *swap* path's guarantee.
            let Some((got, e)) = run(engine(12, 160, policy)) else {
                return false;
            };
            total_swaps.set(total_swaps.get() + e.metrics.swap_outs);
            total_preempts.set(total_preempts.get() + e.metrics.preemptions);
            expected == got
                && e.cache_stats().blocks_used == 0
                && e.tier_stats().host_used_blocks == 0
                && e.tier_stats().swapped_seqs == 0
                && e.metrics.prefetch_hits + e.metrics.prefetch_misses == e.metrics.swap_ins
        },
    );
    assert!(
        total_preempts.get() > 0,
        "the undersized pool must force preemption somewhere in the suite"
    );
    assert!(
        total_swaps.get() > 0,
        "the suite must actually exercise the swap path"
    );
}

/// Acceptance: prefix-hash sharing stays correct across tiers at the
/// engine level — requests sharing a long prefix keep their shared blocks
/// intact while one reader lives in the host tier, and outputs still
/// match the unconstrained run.
#[test]
fn prefix_sharing_survives_swap_under_pressure() {
    let shared_prefix: Vec<u32> = (0..16u32).map(|i| 60 + i).collect();
    let mk_reqs = || -> Vec<(Vec<u32>, usize)> {
        (0..6u32)
            .map(|i| {
                let mut toks = shared_prefix.clone();
                toks.extend((0..6u32).map(|t| 120 + i * 7 + t));
                (toks, 10)
            })
            .collect()
    };
    let run = |mut e: Engine<MockBackend>| {
        for (toks, max_new) in mk_reqs() {
            e.submit_tokens(toks, max_new, SamplingParams::default(), false)
                .unwrap();
        }
        let mut r = e.run_to_completion().unwrap();
        r.sort_by_key(|x| x.id);
        (r.into_iter().map(|x| x.tokens).collect::<Vec<_>>(), e)
    };
    let (expected, _) = run(engine(96, 0, SwapPolicy::Never));
    let (got, e) = run(engine(14, 64, SwapPolicy::Always));
    assert_eq!(expected, got, "shared-prefix outputs identical across tiers");
    assert!(e.metrics.preemptions > 0, "pool pressure must preempt");
    assert!(e.metrics.swap_outs > 0, "and the tier manager must swap");
    assert!(
        e.cache_stats().prefix_hits > 0,
        "prefix sharing engaged under the tiered pool"
    );
    assert_eq!(e.cache_stats().blocks_used, 0, "no leaked or doubly-freed blocks");
    assert_eq!(e.tier_stats().host_used_blocks, 0);
}

/// Acceptance: swap invisibility also holds while the *adaptive*
/// speculation controller is changing the draft length over the same
/// pressured pool — mid-speculation preemption rolls reservations back
/// before the victim exits via swap, whatever k the round picked.
#[test]
fn swap_stays_invisible_under_adaptive_speculation() {
    let mk_reqs = || -> Vec<(Vec<u32>, usize)> {
        (0..6u32)
            .map(|i| {
                let toks: Vec<u32> = (0..10 + i % 4).map(|t| 40 + i * 9 + t).collect();
                (toks, 8 + (i as usize % 3))
            })
            .collect()
    };
    let run = |mut e: Engine<MockBackend>| {
        for (toks, max_new) in mk_reqs() {
            e.submit_tokens(toks, max_new, SamplingParams::default(), false)
                .unwrap();
        }
        let mut r = e.run_to_completion().unwrap();
        r.sort_by_key(|x| x.id);
        (r.into_iter().map(|x| x.tokens).collect::<Vec<_>>(), e)
    };
    let (expected, base) = run(engine(96, 0, SwapPolicy::Never));
    assert_eq!(base.metrics.preemptions, 0, "reference must be unconstrained");
    let be = MockBackend::with_geometry(geometry(12)).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(160)
        .with_swap_policy(SwapPolicy::Always)
        .with_adaptive_speculation(3);
    let (got, e) = run(Engine::new(be, cfg));
    assert_eq!(expected, got, "adaptive speculation + swap must not change outputs");
    assert!(e.metrics.preemptions > 0, "pool pressure must preempt");
    assert!(e.metrics.spec_rounds > 0, "the controller actually drafted");
    assert_eq!(e.cache_stats().blocks_used, 0);
    assert_eq!(e.tier_stats().host_used_blocks, 0, "host tier drains");
}

/// Acceptance: under a pool-exhausting workload, the host tier drives
/// tokens-recomputed to ~0 and improves Eq. 12 throughput versus the
/// drop-and-recompute baseline (the numbers the benches publish in
/// BENCH_serve.json).
#[test]
fn swap_beats_recompute_on_pool_exhausting_workload() {
    let rows = run_swap_compare(8, 24).unwrap();
    let base = rows.iter().find(|r| r.mode == "recompute").unwrap();
    let swap = rows.iter().find(|r| r.mode == "swap").unwrap();
    assert_eq!(base.tokens, swap.tokens, "same generated workload");
    assert!(base.preemptions > 0, "workload must exhaust the pool");
    assert!(base.tokens_recomputed > 0, "the baseline pays in recompute");
    assert!(swap.swap_outs > 0 && swap.swap_ins > 0);
    assert!(
        swap.tokens_recomputed * 10 <= base.tokens_recomputed,
        "tiered recompute ~0: {} vs baseline {}",
        swap.tokens_recomputed,
        base.tokens_recomputed
    );
    assert!(
        swap.throughput_sim > base.throughput_sim,
        "throughput: swap {} <= recompute {}",
        swap.throughput_sim,
        base.throughput_sim
    );
    assert!(
        swap.recompute_avoided_tokens > 0,
        "avoided-recompute accounting engaged"
    );
}

/// Acceptance: watermark-based proactive eviction (`--evict-watermark`,
/// default off) swaps the preemption-order victim *ahead of demand*
/// when device free blocks dip below the watermark.  It must stay
/// token-identical to the unconstrained run, account its moves
/// separately (`proactive_swap_outs`), never engage when the knob is
/// off, and still drain both tiers to zero.
#[test]
fn watermark_eviction_swaps_ahead_of_demand_and_stays_exact() {
    let mut rng = Rng::new(0xE71C);
    let reqs: Vec<(Vec<u32>, usize)> = (0..8)
        .map(|_| {
            let len = 8 + rng.below(20) as usize;
            let toks: Vec<u32> = (0..len).map(|_| 33 + rng.below(200) as u32).collect();
            (toks, 4 + rng.below(8) as usize)
        })
        .collect();
    let run = |mut e: Engine<MockBackend>| {
        for (toks, max_new) in &reqs {
            e.submit_tokens(toks.clone(), *max_new, SamplingParams::default(), false)
                .unwrap();
        }
        let mut r = e.run_to_completion().unwrap();
        r.sort_by_key(|x| x.id);
        (r.into_iter().map(|x| x.tokens).collect::<Vec<_>>(), e)
    };
    let (expected, _) = run(engine(96, 0, SwapPolicy::Never));
    // knob off (the default): pressure preempts on demand only
    let (got_off, off) = run(engine(12, 160, SwapPolicy::Always));
    assert_eq!(expected, got_off);
    assert_eq!(off.metrics.proactive_swap_outs, 0, "watermark defaults to off");
    // knob on: free-block dips trigger ahead-of-demand swap-outs
    let be = MockBackend::with_geometry(geometry(12)).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(160)
        .with_swap_policy(SwapPolicy::Always)
        .with_evict_watermark(6);
    let (got_on, on) = run(Engine::new(be, cfg));
    assert_eq!(expected, got_on, "proactive eviction changed outputs");
    assert!(
        on.metrics.proactive_swap_outs > 0,
        "watermark 6 over a 12-block pool never triggered"
    );
    assert!(
        on.metrics.swap_outs >= on.metrics.proactive_swap_outs,
        "proactive moves are a subset of all swap-outs"
    );
    assert_eq!(on.cache_stats().blocks_used, 0, "device pool drains");
    assert_eq!(on.tier_stats().host_used_blocks, 0, "host tier drains");
}
