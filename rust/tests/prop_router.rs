//! Property + acceptance tests for multi-replica routing.
//!
//! The contract under test: **routing is semantically invisible** — for
//! every routing policy and replica count, an N-replica router returns
//! token-identical per-request outputs to a single engine under greedy
//! sampling, in submission order.  Placement may move a request to any
//! replica (and with it the cluster's throughput and prefix-hit
//! profile), but never what the request gets back.  The acceptance test
//! pins the bench gates: on the default skewed multi-tenant trace at
//! N = 4, least_loaded beats round_robin on cluster Eq. 12 throughput
//! and prefix_affinity beats both on the cluster prefix-hit rate.

use llm_coopt::config::{EngineConfig, RouterPolicy, COOPT};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::router::Router;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::util::quickprop::{check, gens};
use llm_coopt::workload::harness::run_router_compare;
use llm_coopt::workload::MultiTenantSpec;

fn mock_engine() -> Engine<MockBackend> {
    Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    )
}

/// Property: 40 random multi-tenant workloads, each checked across all
/// three policies at N ∈ {1, 2, 3} — 360 cluster runs against their
/// single-engine reference outputs.
#[test]
fn routing_is_token_identical_to_single_engine() {
    check(
        40,
        gens::pair(gens::vec(gens::usize_to(11), 1..=10), gens::usize_to(1000)),
        |&(ref profile, seed): &(Vec<usize>, usize)| {
            // each profile entry is one request: a tenant-shared prefix
            // (exercises affinity keys) plus a unique tail, and a small
            // per-request decode budget
            let reqs: Vec<GenRequest> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| {
                    let tenant = p % 3;
                    GenRequest::greedy(
                        format!(
                            "tenantprop{tenant} {} tail {seed} {i} {}",
                            "s".repeat(20 + 2 * tenant),
                            "y".repeat(p)
                        ),
                        2 + (p + seed) % 6,
                    )
                })
                .collect();
            let mut single = mock_engine();
            let base = single.generate(reqs.clone()).unwrap();
            for n in [1usize, 2, 3] {
                for policy in RouterPolicy::ALL {
                    let engines: Vec<Engine<MockBackend>> =
                        (0..n).map(|_| mock_engine()).collect();
                    let mut router = Router::new(engines, policy);
                    for r in &reqs {
                        router.submit(r.clone()).unwrap();
                    }
                    let got = router.run_to_completion().unwrap();
                    if got.len() != base.len() {
                        return false;
                    }
                    for (a, b) in base.iter().zip(&got) {
                        if a.tokens != b.result.tokens
                            || a.finish != b.result.finish
                            || b.replica >= n
                        {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Acceptance: the CI bench gates hold on the default trace, so `cargo
/// test` catches a routing regression without running the bench.
#[test]
fn router_compare_gates_hold_on_default_trace() {
    let rows = run_router_compare(&[1, 4], &MultiTenantSpec::default()).unwrap();
    let at = |policy: &str, n: usize| {
        rows.iter()
            .find(|r| {
                r.req_str("policy").unwrap() == policy && r.req_usize("replicas").unwrap() == n
            })
            .unwrap()
    };
    let rr = at("round_robin", 4);
    let ll = at("least_loaded", 4);
    let pa = at("prefix_affinity", 4);
    // Eq. 12: balancing the makespan raises cluster throughput
    assert!(
        ll.req_f64("cluster_throughput_sim").unwrap()
            > rr.req_f64("cluster_throughput_sim").unwrap(),
        "least_loaded {:.2} tok/s must beat round_robin {:.2}",
        ll.req_f64("cluster_throughput_sim").unwrap(),
        rr.req_f64("cluster_throughput_sim").unwrap()
    );
    assert!(
        ll.req_f64("busy_spread").unwrap() <= rr.req_f64("busy_spread").unwrap(),
        "least_loaded must not spread busy time worse than round_robin"
    );
    // placement-aware cache reuse: affinity wins the cluster hit rate
    assert!(
        pa.req_f64("prefix_hit_rate").unwrap() > rr.req_f64("prefix_hit_rate").unwrap(),
        "prefix_affinity {:.3} hit rate must beat round_robin {:.3}",
        pa.req_f64("prefix_hit_rate").unwrap(),
        rr.req_f64("prefix_hit_rate").unwrap()
    );
    assert!(
        pa.req_f64("prefix_hit_rate").unwrap() >= ll.req_f64("prefix_hit_rate").unwrap()
    );
    // N = 1 degeneracy: one replica makes every policy the same cluster
    let r1 = at("round_robin", 1);
    for p in ["least_loaded", "prefix_affinity"] {
        let o = at(p, 1);
        assert_eq!(
            o.req_usize("prefix_hits").unwrap(),
            r1.req_usize("prefix_hits").unwrap()
        );
        assert!(
            (o.req_f64("cluster_throughput_sim").unwrap()
                - r1.req_f64("cluster_throughput_sim").unwrap())
            .abs()
                < 1e-9
        );
    }
    // the harness bails on any output divergence; the flag records it
    for r in &rows {
        assert!(r.req_bool("token_identical").unwrap());
    }
}
