//! Cross-language equivalence: the rust PJRT runtime must reproduce the
//! python serving path's logits (golden.json, written by `compile.aot`)
//! for every opt config — this pins L1 (Pallas kernels), L2 (jax model),
//! the HLO-text interchange, and the runtime's buffer plumbing at once.
//!
//! Requires `make artifacts`; tests no-op (with a loud eprintln) otherwise.

use llm_coopt::config::{artifacts_dir, opt_config, ALL_CONFIGS};
use llm_coopt::runtime::{artifacts_available, Backend, Runtime};
use llm_coopt::util::json;

fn load_golden() -> Option<json::Value> {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        return None;
    }
    let text = std::fs::read_to_string(dir.join("golden.json")).ok()?;
    Some(json::parse(&text).expect("golden.json parses"))
}

fn as_f32_vec(v: &json::Value) -> Vec<f32> {
    v.as_array()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn runtime_matches_python_golden_all_configs() {
    let Some(golden) = load_golden() else { return };
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).expect("runtime");
    let model = golden.req_str("model").unwrap();
    let prompt: Vec<i32> = golden
        .req_array("prompt_tokens")
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect();
    let g = rt.manifest.geometry;
    let t = prompt.len();

    for cfg in ALL_CONFIGS {
        let expected = golden.req("configs").unwrap().req(cfg.name).unwrap();
        let mut mrt = rt.load_model(model, cfg).expect("load model");

        // --- prefill, mirroring write_golden's slot layout
        let mut toks = vec![256i32; g.max_seq];
        toks[..t].copy_from_slice(&prompt);
        let mut slots = vec![-1i32; g.max_seq];
        let upto = if cfg.skip_filter { t } else { g.max_seq };
        for (i, s) in slots.iter_mut().enumerate().take(upto) {
            *s = i as i32;
        }
        let logits = mrt.prefill(&toks, t as i32, &slots).expect("prefill");
        let vocab = mrt.preset().vocab;
        let got = &logits[(t - 1) * vocab..t * vocab];
        let want = as_f32_vec(expected.req("prefill_last").unwrap());
        let d = max_abs_diff(got, &want);
        assert!(d < 2e-3, "{}: prefill logits diverge by {d}", cfg.name);

        // --- two decode steps
        for step in expected.req_array("decode_steps").unwrap() {
            let tok = step.req_usize("token").unwrap() as i32;
            let pos = step.req_usize("position").unwrap() as i32;
            let mut token_ids = vec![256i32; g.max_batch];
            token_ids[0] = tok;
            let mut positions = vec![0i32; g.max_batch];
            positions[0] = pos;
            let mut ctx = vec![0i32; g.max_batch];
            ctx[0] = pos + 1;
            let mut sm = vec![-1i32; g.max_batch];
            sm[0] = pos;
            let mut bt = vec![0i32; g.max_batch * g.max_blocks];
            for (i, b) in bt.iter_mut().enumerate().take(g.max_blocks) {
                *b = i as i32;
            }
            let logits = mrt
                .decode(&token_ids, &positions, &bt, &ctx, &sm)
                .expect("decode");
            let got = &logits[..vocab];
            let want = as_f32_vec(step.req("logits").unwrap());
            let d = max_abs_diff(got, &want);
            assert!(d < 2e-3, "{}: decode@{pos} diverges by {d}", cfg.name);
        }
        println!("config {} matches golden", cfg.name);
    }
}

#[test]
fn cache_reset_restores_initial_state() {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let cfg = opt_config("coopt").unwrap();
    let mut mrt = rt.load_model("llama-7b-sim", cfg).unwrap();
    let g = rt.manifest.geometry;

    let mut toks = vec![256i32; g.max_seq];
    for (i, tk) in toks.iter_mut().enumerate().take(8) {
        *tk = 65 + i as i32;
    }
    let mut slots = vec![-1i32; g.max_seq];
    for (i, s) in slots.iter_mut().enumerate().take(8) {
        *s = i as i32;
    }
    let a = mrt.prefill(&toks, 8, &slots).unwrap();
    mrt.reset_cache().unwrap();
    let b = mrt.prefill(&toks, 8, &slots).unwrap();
    assert_eq!(a, b, "prefill after reset must be identical");
}

#[test]
fn decode_is_deterministic_given_cache_state() {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let cfg = opt_config("original").unwrap();
    let mut mrt = rt.load_model("llama-7b-sim", cfg).unwrap();
    let g = rt.manifest.geometry;

    let mut toks = vec![256i32; g.max_seq];
    toks[0] = 100;
    toks[1] = 101;
    let mut slots = vec![-1i32; g.max_seq];
    // original writes padded positions too
    for (i, s) in slots.iter_mut().enumerate() {
        *s = i as i32;
    }
    mrt.prefill(&toks, 2, &slots).unwrap();

    // same decode twice from the same cache state: the second call rewrites
    // the same slot with the same value, so logits must repeat
    let mut token_ids = vec![256i32; g.max_batch];
    token_ids[0] = 102;
    let mut positions = vec![0i32; g.max_batch];
    positions[0] = 2;
    let mut ctx = vec![0i32; g.max_batch];
    ctx[0] = 3;
    let mut sm = vec![-1i32; g.max_batch];
    sm[0] = 2;
    let mut bt = vec![0i32; g.max_batch * g.max_blocks];
    for (i, b) in bt.iter_mut().enumerate().take(g.max_blocks) {
        *b = i as i32;
    }
    let l1 = mrt.decode(&token_ids, &positions, &bt, &ctx, &sm).unwrap();
    let l2 = mrt.decode(&token_ids, &positions, &bt, &ctx, &sm).unwrap();
    assert_eq!(l1, l2);
    let vocab = mrt.preset().vocab;
    assert!(l1[..vocab].iter().all(|x| x.is_finite()));
}
