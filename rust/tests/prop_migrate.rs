//! Property tests for disaggregated prefill/decode hand-off: migrating
//! a sequence's KV between replicas through the host tier must be
//! *semantically invisible*.  For every PD role layout, routing policy,
//! and random burst/steady workload mix, a cluster with hand-off
//! enabled returns token-identical per-request outputs to a single
//! unconstrained engine — including when the hand-off races preemption
//! and swap on an undersized device pool.  The mock backend enforces
//! copy semantics (residency contract) on every decode, so each case
//! doubles as a migration-correctness check: an exported block that
//! landed wrong would change the tokens, not just the timing.

use std::cell::Cell;

use llm_coopt::config::{
    CacheGeometry, EngineConfig, ReplicaRole, RouterPolicy, SwapPolicy, COOPT,
};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::router::Router;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::util::quickprop::{check, gens};

fn geometry(pool_blocks: usize) -> CacheGeometry {
    CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: pool_blocks,
        max_batch: 4,
        max_seq: 48,
    }
}

/// The host tier is sized for the worst case so preemption always
/// swaps: the recompute fallback re-samples a decoded tail token
/// through the prefill function, which the mock deliberately
/// distinguishes from decode — exact equality is the swap and
/// migration paths' guarantee, not recompute's.
fn pd_engine(pool_blocks: usize, role: ReplicaRole) -> Engine<MockBackend> {
    let be = MockBackend::with_geometry(geometry(pool_blocks)).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(160)
        .with_swap_policy(SwapPolicy::Always)
        .with_role(role);
    Engine::new(be, cfg)
}

const ROLE_SETS: [&[ReplicaRole]; 4] = [
    &[ReplicaRole::Prefill, ReplicaRole::Decode],
    &[ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed],
    &[ReplicaRole::Prefill, ReplicaRole::Prefill, ReplicaRole::Decode],
    &[ReplicaRole::Mixed, ReplicaRole::Decode],
];

/// Property: ≥ 100 random workloads, each a mix of prefill-heavy burst
/// requests (past the 4x dominance gate, so the unpriced router always
/// hands them off) and decode-heavy steady requests, run through a PD
/// cluster whose device pools are undersized to force preemption and
/// swap *while* hand-offs are in flight.  Greedy outputs must match the
/// unconstrained single engine token for token, every tier must drain
/// to zero, and the suite as a whole must actually migrate and preempt.
#[test]
fn pd_handoff_is_token_identical_over_random_workloads() {
    let total_migrations = Cell::new(0u64);
    let total_fallbacks = Cell::new(0u64);
    let total_preempts = Cell::new(0u64);
    check(
        120,
        gens::pair(gens::vec(gens::usize_to(23), 1..=8), gens::usize_to(1000)),
        |&(ref profile, seed): &(Vec<usize>, usize)| {
            let reqs: Vec<GenRequest> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| {
                    if p % 2 == 0 {
                        // prefill-heavy burst: long prompt, tiny decode
                        // budget (max 40 prompt + 4 new = 11 of the 12
                        // pool blocks, so it fits but races preemption)
                        GenRequest::greedy(
                            format!("mig{seed} {i} {}", "b".repeat(5 + p)),
                            2 + p % 3,
                        )
                    } else {
                        // steady decode-heavy stream, under the gate
                        GenRequest::greedy(
                            format!("st{seed} {i} {}", "s".repeat(p % 7)),
                            4 + p % 8,
                        )
                    }
                })
                .collect();
            // unconstrained reference: one engine, big pool, single tier
            let mut single = Engine::new(
                MockBackend::with_geometry(geometry(96)).with_opt(COOPT),
                EngineConfig::new("llama-7b-sim", COOPT),
            );
            let base = single.generate(reqs.clone()).unwrap();
            if single.metrics.preemptions != 0 {
                return false; // reference must be genuinely unconstrained
            }
            let roles = ROLE_SETS[seed % ROLE_SETS.len()];
            let policy = RouterPolicy::ALL[profile.len() % RouterPolicy::ALL.len()];
            let engines: Vec<Engine<MockBackend>> =
                roles.iter().map(|&r| pd_engine(12, r)).collect();
            let mut router = Router::new(engines, policy).with_unpriced_handoff();
            for r in &reqs {
                router.submit(r.clone()).unwrap();
            }
            let got = router.run_to_completion().unwrap();
            if got.len() != base.len() {
                return false;
            }
            for (a, b) in base.iter().zip(&got) {
                if a.tokens != b.result.tokens
                    || a.finish != b.result.finish
                    || b.replica >= roles.len()
                {
                    return false;
                }
            }
            for e in router.replicas() {
                total_migrations.set(total_migrations.get() + e.metrics.migrations_out);
                total_fallbacks
                    .set(total_fallbacks.get() + e.metrics.migrations_token_fallback);
                total_preempts.set(total_preempts.get() + e.metrics.preemptions);
                // both tiers drain: no leaked device blocks, host slots,
                // swapped residue, or half-migrated sequences
                if e.cache_stats().blocks_used != 0
                    || e.tier_stats().host_used_blocks != 0
                    || e.tier_stats().swapped_seqs != 0
                    || e.num_migrating() != 0
                {
                    return false;
                }
            }
            true
        },
    );
    assert!(
        total_migrations.get() > 0,
        "the suite must actually exercise the KV hand-off path"
    );
    assert!(
        total_preempts.get() > 0,
        "the undersized pools must force preemption racing the hand-offs"
    );
    // the fallback path (KV could not land: full batch or pool on the
    // destination) is allowed, but must never dominate: deferral keeps
    // most hand-offs on the exact-KV path
    assert!(
        total_fallbacks.get() <= total_migrations.get(),
        "token fallback dominated the hand-off path ({} of {})",
        total_fallbacks.get(),
        total_migrations.get()
    );
}

/// Acceptance: a drained prefill tier must not strand its parked
/// sequences — with every decode-capable destination draining, the
/// hand-off aborts back to local decode and the outputs still match.
#[test]
fn handoff_with_drained_destinations_aborts_to_local_decode() {
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest::greedy(format!("drain {i} {}", "d".repeat(24 + i)), 3))
        .collect();
    let mut single = Engine::new(
        MockBackend::with_geometry(geometry(96)).with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base = single.generate(reqs.clone()).unwrap();

    let engines = vec![pd_engine(24, ReplicaRole::Prefill), pd_engine(24, ReplicaRole::Decode)];
    let mut router = Router::new(engines, RouterPolicy::LeastLoaded).with_unpriced_handoff();
    for r in &reqs {
        router.submit(r.clone()).unwrap();
    }
    // the only decode-capable replica starts draining after placement:
    // parked sequences have nowhere to go and must finish where they are
    router.set_draining(1, true);
    let got = router.run_to_completion().unwrap();
    assert_eq!(got.len(), base.len());
    for (a, b) in base.iter().zip(&got) {
        assert_eq!(a.tokens, b.result.tokens, "abort-to-local-decode changed outputs");
        assert_eq!(b.replica, 0, "draining destination must not receive hand-offs");
    }
    let m: u64 = router.replicas().iter().map(|e| e.metrics.migrations_out).sum();
    assert_eq!(m, 0, "no hand-off may leave the cluster while the decode tier drains");
}
