//! Property + acceptance tests for speculative decoding (draft-and-verify).
//!
//! The contract under test: **greedy speculation is semantically
//! invisible** — for every opt config, random workloads, random draft
//! lengths, and a device pool small enough to force preemption (including
//! preemption *mid-speculation*, while a lane holds reserved verify
//! slots), the speculative engine's outputs are token-for-token identical
//! to one-token greedy decode, and the KV rollback path leaks nothing.
//! The mock backend enforces the decode/verify residency and padding
//! contracts on every call, so each case doubles as a correctness check
//! of `CacheManager::truncate_seq` under real allocation churn.

use std::cell::Cell;

use llm_coopt::config::{CacheGeometry, EngineConfig, SwapPolicy, ALL_CONFIGS};
use llm_coopt::coordinator::Engine;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::sampling::SamplingParams;
use llm_coopt::util::quickprop::{check, gens};
use llm_coopt::util::rng::Rng;
use llm_coopt::workload::harness::run_spec_compare;

fn geometry(pool_blocks: usize) -> CacheGeometry {
    CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: pool_blocks,
        max_batch: 4,
        max_seq: 48,
    }
}

/// Acceptance: ≥ 120 random cases across all five opt configs
/// (original/optkv/optgqa/optpa/coopt).  The reference is an
/// unconstrained one-token greedy run; the speculative run uses an
/// undersized pool with a host tier sized so preemption always exits via
/// swap (recompute re-samples through the prefill function, which the
/// mock deliberately distinguishes — exactness is the swap+speculation
/// guarantee, as in prop_swap).
#[test]
fn greedy_speculation_is_exact_for_every_opt_config() {
    let total_spec_rounds = Cell::new(0u64);
    let total_preemptions = Cell::new(0u64);
    let total_rejections = Cell::new(0u64);
    check(
        130,
        gens::pair(
            gens::vec(gens::usize_to(11), 1..=6),
            gens::pair(gens::usize_to(3), gens::usize_to(1000)),
        ),
        |&(ref profile, (k0, seed)): &(Vec<usize>, (usize, usize))| {
            let k = 1 + k0; // draft length 1..=4
            let opt = ALL_CONFIGS[seed % ALL_CONFIGS.len()];
            // 14 blocks: the padded baseline (12 blocks of padding + 1
            // headroom) can still admit, while SkipSet configs running
            // several grown sequences exhaust the pool and preempt
            let pool = 14;
            let mut rng = Rng::new(seed as u64 ^ 0x5bec);
            let reqs: Vec<(Vec<u32>, usize)> = profile
                .iter()
                .map(|&p| {
                    let len = 1 + p; // 1..=12 prompt tokens
                    let toks: Vec<u32> =
                        (0..len).map(|_| 33 + rng.below(200) as u32).collect();
                    (toks, 2 + p % 8)
                })
                .collect();
            let run = |spec: usize, pool_blocks: usize, host: usize| {
                let be = MockBackend::with_geometry(geometry(pool_blocks)).with_opt(opt);
                let mut cfg = EngineConfig::new("llama-7b-sim", opt)
                    .with_host_pool(host)
                    .with_swap_policy(SwapPolicy::Always);
                if spec > 0 {
                    cfg = cfg.with_speculation(spec);
                }
                let mut e = Engine::new(be, cfg);
                for (toks, max_new) in &reqs {
                    e.submit_tokens(toks.clone(), *max_new, SamplingParams::default(), false)
                        .unwrap();
                }
                let mut r = match e.run_to_completion() {
                    Ok(r) => r,
                    Err(_) => return None,
                };
                r.sort_by_key(|x| x.id);
                Some((
                    r.into_iter()
                        .map(|x| (x.tokens, x.finish))
                        .collect::<Vec<_>>(),
                    e,
                ))
            };
            // unconstrained one-token reference
            let Some((expected, base)) = run(0, 96, 0) else {
                return false;
            };
            if base.metrics.preemptions != 0 {
                return false; // reference must be genuinely unconstrained
            }
            // speculative run under pool pressure, swap-exit preemption
            let Some((got, e)) = run(k, pool, 160) else {
                return false;
            };
            total_spec_rounds.set(total_spec_rounds.get() + e.metrics.spec_rounds);
            total_preemptions.set(total_preemptions.get() + e.metrics.preemptions);
            total_rejections
                .set(total_rejections.get() + (e.metrics.spec_drafted - e.metrics.spec_accepted));
            expected == got
                && e.cache_stats().blocks_used == 0
                && e.tier_stats().host_used_blocks == 0
                && e.tier_stats().swapped_seqs == 0
                && e.metrics.spec_accepted <= e.metrics.spec_drafted
        },
    );
    assert!(
        total_spec_rounds.get() > 0,
        "the suite must actually run verify passes"
    );
    assert!(
        total_preemptions.get() > 0,
        "the undersized pool must force preemption somewhere in the suite \
         (including mid-speculation rollback)"
    );
    assert!(
        total_rejections.get() > 0,
        "the draft must be rejected somewhere, or rollback is never exercised"
    );
}

/// Acceptance (adaptive speculation): the controller changing k between
/// rounds — cold-start probe, ±1 steps, per-lane and global demotion,
/// re-probing — must stay token-for-token identical to one-token greedy
/// decode across all five opt configs, under the same undersized-pool /
/// swap-exit preemption setup as the fixed-k property.  Divergence and
/// k_max vary per case so the controller actually moves: the suite
/// asserts controller transitions, verify rounds, preemptions, and
/// rejections all occurred somewhere.
#[test]
fn adaptive_greedy_speculation_is_exact_while_k_changes() {
    let total_spec_rounds = Cell::new(0u64);
    let total_preemptions = Cell::new(0u64);
    let total_transitions = Cell::new(0u64);
    let distinct_ks = Cell::new(0u64);
    check(
        120,
        gens::pair(
            gens::vec(gens::usize_to(11), 1..=6),
            gens::pair(gens::usize_to(3), gens::usize_to(1000)),
        ),
        |&(ref profile, (km0, seed)): &(Vec<usize>, (usize, usize))| {
            let k_max = 1 + km0; // adaptive search bound 1..=4
            let opt = ALL_CONFIGS[seed % ALL_CONFIGS.len()];
            // vary the draft quality so the controller's estimate —
            // and therefore k — actually moves across the suite
            let divergence = [2u64, 3, 5, 10][seed % 4];
            let pool = 14;
            let mut rng = Rng::new(seed as u64 ^ 0xADA7);
            let reqs: Vec<(Vec<u32>, usize)> = profile
                .iter()
                .map(|&p| {
                    let len = 1 + p; // 1..=12 prompt tokens
                    let toks: Vec<u32> =
                        (0..len).map(|_| 33 + rng.below(200) as u32).collect();
                    (toks, 2 + p % 8)
                })
                .collect();
            let run = |adaptive: bool, pool_blocks: usize, host: usize| {
                let mut be = MockBackend::with_geometry(geometry(pool_blocks)).with_opt(opt);
                be.draft_divergence = divergence;
                let mut cfg = EngineConfig::new("llama-7b-sim", opt)
                    .with_host_pool(host)
                    .with_swap_policy(SwapPolicy::Always);
                if adaptive {
                    cfg = cfg.with_adaptive_speculation(k_max);
                }
                let mut e = Engine::new(be, cfg);
                for (toks, max_new) in &reqs {
                    e.submit_tokens(toks.clone(), *max_new, SamplingParams::default(), false)
                        .unwrap();
                }
                let mut r = match e.run_to_completion() {
                    Ok(r) => r,
                    Err(_) => return None,
                };
                r.sort_by_key(|x| x.id);
                Some((
                    r.into_iter()
                        .map(|x| (x.tokens, x.finish))
                        .collect::<Vec<_>>(),
                    e,
                ))
            };
            // unconstrained one-token reference
            let Some((expected, base)) = run(false, 96, 0) else {
                return false;
            };
            if base.metrics.preemptions != 0 {
                return false;
            }
            // adaptive run under pool pressure, swap-exit preemption
            let Some((got, e)) = run(true, pool, 160) else {
                return false;
            };
            total_spec_rounds.set(total_spec_rounds.get() + e.metrics.spec_rounds);
            total_preemptions.set(total_preemptions.get() + e.metrics.preemptions);
            total_transitions.set(total_transitions.get() + e.metrics.spec_ctrl_transitions);
            let ks_used = e
                .metrics
                .spec_k_hist
                .iter()
                .filter(|&&n| n > 0)
                .count() as u64;
            distinct_ks.set(distinct_ks.get().max(ks_used));
            expected == got
                && e.cache_stats().blocks_used == 0
                && e.tier_stats().host_used_blocks == 0
                && e.tier_stats().swapped_seqs == 0
                && e.metrics.spec_accepted <= e.metrics.spec_drafted
        },
    );
    assert!(
        total_spec_rounds.get() > 0,
        "the suite must actually run verify passes"
    );
    assert!(
        total_preemptions.get() > 0,
        "the undersized pool must force preemption under the controller"
    );
    assert!(
        total_transitions.get() > 0,
        "the controller must actually change k somewhere in the suite"
    );
    assert!(
        distinct_ks.get() >= 2,
        "some run must mix draft lengths (k actively changing mid-stream)"
    );
}

/// Acceptance: the bench comparison the CI smoke publishes —
/// tokens_per_step > 1 under speculation, token-identical outputs
/// (asserted inside run_spec_compare), and an Eq. 12 throughput win at
/// the mock's high acceptance rate.
#[test]
fn speculation_beats_one_token_decode_on_the_cost_model() {
    let rows = run_spec_compare(3, 24, &[2, 4]).unwrap();
    let base = &rows[0];
    assert_eq!(base.mode, "baseline");
    assert!((base.tokens_per_step - 1.0).abs() < 1e-9);
    for r in &rows[1..] {
        assert_eq!(r.tokens, base.tokens, "{}: same generated workload", r.mode);
        assert!(
            r.tokens_per_step > 1.0,
            "{}: tokens/step {} must exceed one",
            r.mode,
            r.tokens_per_step
        );
        assert!(
            r.decode_rounds < base.decode_rounds,
            "{}: fewer rounds than one-token decode",
            r.mode
        );
        assert!(
            r.acceptance_rate > 0.5,
            "{}: the tuned mock draft should mostly agree ({})",
            r.mode,
            r.acceptance_rate
        );
        assert!(
            r.throughput_sim > base.throughput_sim,
            "{}: throughput {} <= baseline {}",
            r.mode,
            r.throughput_sim,
            base.throughput_sim
        );
    }
}
