//! HTTP server over the real PJRT engine: end-to-end request -> tokens ->
//! JSON response, plus concurrent batched clients.  SKIPs without artifacts.

use std::sync::atomic::Ordering;

use llm_coopt::config::{artifacts_dir, EngineConfig, COOPT};
use llm_coopt::coordinator::Engine;
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::server::{Client, EngineHandle, Server};
use llm_coopt::util::threadpool::ThreadPool;

#[test]
fn http_serving_end_to_end() {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let mrt = rt.load_model("llama-7b-sim", COOPT).unwrap();
    let engine = Engine::new(mrt, EngineConfig::new("llama-7b-sim", COOPT));
    let handle = EngineHandle::spawn(engine);
    let server = Server::bind("127.0.0.1:0", handle, 4).unwrap();
    let addr = server.addr.to_string();
    let stop = server.stop_flag();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // health
    let client = Client::new(addr.clone());
    let (code, v) = client.get("/health").unwrap();
    assert_eq!(code, 200);
    assert_eq!(v.req_str("status").unwrap(), "ok");

    // single generation (trained models may stop early at EOS)
    let v = client.generate("Q: 1+2=? Answer:", 4).unwrap();
    let got = v.req_usize("generated_tokens").unwrap();
    assert!((1..=4).contains(&got), "generated {got}");
    assert!(v.req_f64("latency_s").unwrap() > 0.0);
    assert!(v.req_f64("sim_time_s").unwrap() > 0.0);

    // concurrent clients batch inside the engine
    let pool = ThreadPool::new(4);
    let addr2 = addr.clone();
    let counts = pool.map((0..4).collect::<Vec<u32>>(), move |i| {
        Client::new(addr2.clone())
            .generate(&format!("Q: {i}+{i}=? Answer:"), 3)
            .map(|v| v.req_usize("generated_tokens").unwrap())
    });
    let mut total = got;
    for c in counts {
        let n = c.unwrap();
        assert!((1..=3).contains(&n), "generated {n}");
        total += n;
    }

    // metrics reflect the traffic
    let (code, m) = client.get("/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(m.req_usize("tokens_generated").unwrap() >= total);

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap();
}
