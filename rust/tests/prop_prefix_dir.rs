//! Property tests for cluster-wide prefix reuse: routing through the
//! global [`PrefixDirectory`] — including cross-replica KV pulls — must
//! be *semantically invisible*.  Over random multi-tenant workloads,
//! replica counts, role layouts, arrival pacings, and deliberately
//! poisoned directory state, a directory-routed cluster returns
//! token-identical per-request outputs to a single unconstrained
//! engine, while undersized device pools force eviction and swap to
//! race the pulls.  The mock backend enforces copy semantics (residency
//! contract) on every decode, so each case doubles as a
//! pull-correctness check: a pulled block that landed wrong would
//! change the tokens, not just the timing.  Stale directory entries
//! (wrong owner, evicted chain) may only ever cost a shorter pull and a
//! re-prefill — never a wrong token.

use std::cell::Cell;

use llm_coopt::config::{
    CacheGeometry, EngineConfig, ReplicaRole, RouterPolicy, SwapPolicy, COOPT,
};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::kvcache::prefix_chain_hashes;
use llm_coopt::router::Router;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::tokenizer::Tokenizer;
use llm_coopt::util::quickprop::{check, gens};

fn geometry(pool_blocks: usize) -> CacheGeometry {
    CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: pool_blocks,
        max_batch: 4,
        max_seq: 48,
    }
}

/// Replica with a device pool small enough that concurrent sequences
/// preempt and swap while pulls are in flight; the host tier is sized
/// for the worst case so preemption never drops to the recompute
/// fallback (exact equality is the swap/pull paths' guarantee).
fn dir_engine(pool_blocks: usize, role: ReplicaRole) -> Engine<MockBackend> {
    let be = MockBackend::with_geometry(geometry(pool_blocks)).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(160)
        .with_swap_policy(SwapPolicy::Always)
        .with_role(role);
    Engine::new(be, cfg)
}

/// Property: ≥ 120 random multi-tenant workloads driven open-loop
/// (0..=2 cluster steps per arrival, so earlier requests' prefix chains
/// are live — or freshly evicted — when later ones route) through a
/// directory-routed cluster of 2..=4 replicas.  Every third case
/// poisons the directory with wrong owners for the incoming request's
/// own chain before routing it, forcing pulls against replicas that may
/// hold none (or only some) of the claimed blocks.  Half the cases add
/// a prefill-role replica so PD hand-offs race the pulls too.  Outputs
/// must match the unconstrained single engine token for token, every
/// tier must drain to zero, and the suite as a whole must actually
/// pull, go stale, and preempt.
#[test]
fn directory_routing_is_token_identical_over_random_workloads() {
    let total_pulls = Cell::new(0u64);
    let total_pull_blocks = Cell::new(0u64);
    let total_stale = Cell::new(0u64);
    let total_preempts = Cell::new(0u64);
    check(
        120,
        gens::pair(gens::vec(gens::usize_to(20), 2..=10), gens::usize_to(1_000_000)),
        |&(ref profile, seed): &(Vec<usize>, usize)| {
            let tenants = 2 + seed % 3;
            let reqs: Vec<GenRequest> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| {
                    let t = (p + i) % tenants;
                    // the tenant prefix spans 4+ full 4-token blocks;
                    // the user tail diverges per request (kept short:
                    // prompt + max_new must stay inside max_seq 48)
                    let sys = format!("tenant{t} {}", "s".repeat(8 + t * 2));
                    GenRequest::greedy(
                        format!("{sys} u{} {i} {}", seed % 1000, "x".repeat(p % 5)),
                        2 + p % 6,
                    )
                })
                .collect();
            // unconstrained reference: one engine, big pool, single tier
            let mut single = Engine::new(
                MockBackend::with_geometry(geometry(96)).with_opt(COOPT),
                EngineConfig::new("llama-7b-sim", COOPT),
            );
            let base = single.generate(reqs.clone()).unwrap();
            if single.metrics.preemptions != 0 {
                return false; // reference must be genuinely unconstrained
            }
            let n = 2 + seed % 3;
            let engines: Vec<Engine<MockBackend>> = (0..n)
                .map(|i| {
                    // half the cases put a prefill-role replica in the
                    // cluster so PD hand-offs race the prefix pulls
                    let role = if seed % 2 == 0 && i == 0 {
                        ReplicaRole::Prefill
                    } else {
                        ReplicaRole::Mixed
                    };
                    dir_engine(14, role)
                })
                .collect();
            let mut router =
                Router::new(engines, RouterPolicy::Directory).with_unpriced_handoff();
            let tokenizer = Tokenizer::new();
            for (i, r) in reqs.iter().enumerate() {
                if seed % 3 == 0 && i % 2 == 1 {
                    // poison: claim a (likely wrong) replica owns this
                    // request's whole chain — the pull must under-export
                    // and the destination must re-prefill the difference
                    let toks = tokenizer.encode(&r.prompt, true, false);
                    let alive = vec![true; n];
                    for h in prefix_chain_hashes(&toks, 4, 32) {
                        router.directory_mut().register(h, (i + seed) % n, &alive);
                    }
                }
                router.submit(r.clone()).unwrap();
                for _ in 0..((seed + i) % 3) {
                    router.step_all().unwrap();
                }
            }
            let got = router.run_to_completion().unwrap();
            if got.len() != base.len() {
                return false;
            }
            for (a, b) in base.iter().zip(&got) {
                if a.tokens != b.result.tokens
                    || a.finish != b.result.finish
                    || b.replica >= n
                {
                    return false;
                }
            }
            for e in router.replicas() {
                total_pulls.set(total_pulls.get() + e.metrics.prefix_pulls);
                total_pull_blocks
                    .set(total_pull_blocks.get() + e.metrics.prefix_pull_blocks);
                total_stale.set(total_stale.get() + e.metrics.prefix_pull_stale);
                total_preempts.set(total_preempts.get() + e.metrics.preemptions);
                // both tiers drain: no leaked device blocks (pulled pins
                // included), host slots, swapped residue, or
                // half-migrated sequences
                if e.cache_stats().blocks_used != 0
                    || e.tier_stats().host_used_blocks != 0
                    || e.tier_stats().swapped_seqs != 0
                    || e.num_migrating() != 0
                {
                    return false;
                }
            }
            true
        },
    );
    assert!(
        total_pulls.get() > 0,
        "the suite must actually exercise the cross-replica pull path"
    );
    assert!(
        total_pull_blocks.get() > 0,
        "at least some pulls must move real warm blocks, not just go stale"
    );
    assert!(
        total_stale.get() > 0,
        "the poisoned cases must force stale pulls (wrong/evicted owners)"
    );
    assert!(
        total_preempts.get() > 0,
        "the undersized pools must force eviction/swap racing the pulls"
    );
}

/// Acceptance: a cold cluster routed all-upfront (no interleaved
/// stepping) has nothing warm to pull — the directory degenerates to
/// affinity-only placement and must still match the reference exactly,
/// with zero blocks moved.
#[test]
fn cold_directory_degenerates_to_affinity_and_stays_exact() {
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| GenRequest::greedy(format!("cold start {} {}", i % 2, "c".repeat(16 + i)), 4))
        .collect();
    let mut single = Engine::new(
        MockBackend::with_geometry(geometry(96)).with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base = single.generate(reqs.clone()).unwrap();
    let engines: Vec<Engine<MockBackend>> =
        (0..3).map(|_| dir_engine(24, ReplicaRole::Mixed)).collect();
    let mut router = Router::new(engines, RouterPolicy::Directory);
    for r in &reqs {
        router.submit(r.clone()).unwrap();
    }
    let got = router.run_to_completion().unwrap();
    for (a, b) in base.iter().zip(&got) {
        assert_eq!(a.tokens, b.result.tokens, "cold-directory routing changed outputs");
    }
    let pulled: u64 = router
        .replicas()
        .iter()
        .map(|e| e.metrics.prefix_pull_blocks)
        .sum();
    assert_eq!(pulled, 0, "nothing was live to pull on a cold cluster");
}
