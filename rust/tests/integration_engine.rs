//! End-to-end engine tests over the real PJRT runtime + trained weights:
//! batched serving, all five opt configs, output agreement between the
//! baseline and the optimized paths, and the greedy answer path used by
//! the accuracy tables.  SKIPs without artifacts.

use llm_coopt::config::{artifacts_dir, EngineConfig, ALL_CONFIGS, COOPT, ORIGINAL};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::sampling::mcq_scores;
use llm_coopt::tokenizer::Tokenizer;

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

const MODEL: &str = "llama-7b-sim";

#[test]
fn serves_batch_under_every_config() {
    let Some(rt) = runtime() else { return };
    for cfg in ALL_CONFIGS {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest::greedy(format!("Q: {i}+1=? Answer:"), 4))
            .collect();
        let results = engine.generate(reqs).unwrap();
        assert_eq!(results.len(), 5, "{}", cfg.name);
        for r in &results {
            assert!(r.generated_tokens >= 1, "{}", cfg.name);
        }
        assert_eq!(engine.cache_stats().blocks_used, 0, "{}", cfg.name);
        assert!(engine.metrics.sim_decode_s > 0.0);
    }
}

#[test]
fn optimized_paths_agree_with_baseline_greedy() {
    // Opt-Pa is numerically exact; FP8 introduces bounded noise.  On a
    // trained model's confident completions, greedy outputs should agree
    // for the exact configs and mostly agree for FP8.
    let Some(rt) = runtime() else { return };
    let prompts: Vec<String> = (0..4)
        .map(|i| format!("Q: {}+2=? A) {} B) 9 C) 1 D) 3\nAnswer:", i, i + 2))
        .collect();

    let run = |cfg| {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        let reqs = prompts
            .iter()
            .map(|p| GenRequest::greedy(p.clone(), 3))
            .collect();
        engine
            .generate(reqs)
            .unwrap()
            .into_iter()
            .map(|r| r.tokens)
            .collect::<Vec<_>>()
    };
    let base = run(ORIGINAL);
    let pa = run(llm_coopt::config::OPTPA);
    assert_eq!(base, pa, "Opt-Pa must be bit-identical greedy to baseline");
    let coopt = run(COOPT);
    // FP8+GQA: same shape; count agreement instead of demanding equality
    let agree = base.iter().zip(&coopt).filter(|(a, b)| a == b).count();
    assert!(
        agree >= base.len() / 2,
        "coopt agreed on only {agree}/{} greedy completions",
        base.len()
    );
}

#[test]
fn mcq_scoring_path_works_on_real_model() {
    let Some(rt) = runtime() else { return };
    let mrt = rt.load_model(MODEL, COOPT).unwrap();
    let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, COOPT));
    let tok = Tokenizer::new();
    let ids = tok.encode("Q: 2+3=? A) 5 B) 6 C) 4 D) 9\nAnswer: ", true, false);
    let logits = engine.score_tokens(&ids).unwrap();
    assert_eq!(logits.len(), 260);
    let (best, scores) = mcq_scores(&logits, &[65, 66, 67, 68]);
    assert!(best < 4);
    assert!(scores.iter().all(|s| s.is_finite()));
    // trained model puts nontrivial mass on letters after "Answer: "
    let letter_mass: f64 = scores.iter().map(|s| s.exp()).sum();
    assert!(letter_mass > 0.05, "letter mass {letter_mass}");
}

#[test]
fn skip_filter_reduces_writes_and_blocks() {
    let Some(rt) = runtime() else { return };
    let stats_for = |cfg| {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        engine
            .generate(vec![GenRequest::greedy("a short prompt", 2)])
            .unwrap();
        engine.cache_stats()
    };
    let orig = stats_for(ORIGINAL);
    let coopt = stats_for(COOPT);
    assert!(
        coopt.total_writes < orig.total_writes,
        "Opt-KV writes {} < baseline {}",
        coopt.total_writes,
        orig.total_writes
    );
    assert!(coopt.skipped_writes > 0);
    assert_eq!(orig.skipped_writes, 0);
}

#[test]
fn sim_time_orders_configs_like_fig6() {
    let Some(rt) = runtime() else { return };
    let mut total = std::collections::HashMap::new();
    for cfg in [ORIGINAL, COOPT] {
        let mrt = rt.load_model("llama-13b-sim", cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new("llama-13b-sim", cfg));
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(format!("prompt {i} {}", "x".repeat(30)), 8))
            .collect();
        engine.generate(reqs).unwrap();
        total.insert(
            cfg.name,
            engine.metrics.sim_prefill_s + engine.metrics.sim_decode_s,
        );
    }
    assert!(
        total["coopt"] < total["original"],
        "coopt {:?} < original {:?}",
        total["coopt"],
        total["original"]
    );
}
