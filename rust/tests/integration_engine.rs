//! End-to-end engine tests over the real PJRT runtime + trained weights:
//! batched serving, all five opt configs, output agreement between the
//! baseline and the optimized paths, and the greedy answer path used by
//! the accuracy tables.  SKIPs without artifacts.
//!
//! The chunked-prefill section at the bottom runs on the deterministic
//! mock backend and needs no artifacts: long-prompt admission past the
//! step budget, resume-from-offset of partial prefills, preemption
//! recovery, and the p95 decode inter-token latency win.

use llm_coopt::config::{artifacts_dir, CacheGeometry, EngineConfig, ALL_CONFIGS, COOPT, ORIGINAL};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::sampling::{mcq_scores, SamplingParams};
use llm_coopt::tokenizer::Tokenizer;
use llm_coopt::workload::harness::run_chunk_compare;

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

const MODEL: &str = "llama-7b-sim";

#[test]
fn serves_batch_under_every_config() {
    let Some(rt) = runtime() else { return };
    for cfg in ALL_CONFIGS {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest::greedy(format!("Q: {i}+1=? Answer:"), 4))
            .collect();
        let results = engine.generate(reqs).unwrap();
        assert_eq!(results.len(), 5, "{}", cfg.name);
        for r in &results {
            assert!(r.generated_tokens >= 1, "{}", cfg.name);
        }
        assert_eq!(engine.cache_stats().blocks_used, 0, "{}", cfg.name);
        assert!(engine.metrics.sim_decode_s > 0.0);
    }
}

#[test]
fn optimized_paths_agree_with_baseline_greedy() {
    // Opt-Pa is numerically exact; FP8 introduces bounded noise.  On a
    // trained model's confident completions, greedy outputs should agree
    // for the exact configs and mostly agree for FP8.
    let Some(rt) = runtime() else { return };
    let prompts: Vec<String> = (0..4)
        .map(|i| format!("Q: {}+2=? A) {} B) 9 C) 1 D) 3\nAnswer:", i, i + 2))
        .collect();

    let run = |cfg| {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        let reqs = prompts
            .iter()
            .map(|p| GenRequest::greedy(p.clone(), 3))
            .collect();
        engine
            .generate(reqs)
            .unwrap()
            .into_iter()
            .map(|r| r.tokens)
            .collect::<Vec<_>>()
    };
    let base = run(ORIGINAL);
    let pa = run(llm_coopt::config::OPTPA);
    assert_eq!(base, pa, "Opt-Pa must be bit-identical greedy to baseline");
    let coopt = run(COOPT);
    // FP8+GQA: same shape; count agreement instead of demanding equality
    let agree = base.iter().zip(&coopt).filter(|(a, b)| a == b).count();
    assert!(
        agree >= base.len() / 2,
        "coopt agreed on only {agree}/{} greedy completions",
        base.len()
    );
}

#[test]
fn mcq_scoring_path_works_on_real_model() {
    let Some(rt) = runtime() else { return };
    let mrt = rt.load_model(MODEL, COOPT).unwrap();
    let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, COOPT));
    let tok = Tokenizer::new();
    let ids = tok.encode("Q: 2+3=? A) 5 B) 6 C) 4 D) 9\nAnswer: ", true, false);
    let logits = engine.score_tokens(&ids).unwrap();
    assert_eq!(logits.len(), 260);
    let (best, scores) = mcq_scores(&logits, &[65, 66, 67, 68]);
    assert!(best < 4);
    assert!(scores.iter().all(|s| s.is_finite()));
    // trained model puts nontrivial mass on letters after "Answer: "
    let letter_mass: f64 = scores.iter().map(|s| s.exp()).sum();
    assert!(letter_mass > 0.05, "letter mass {letter_mass}");
}

#[test]
fn skip_filter_reduces_writes_and_blocks() {
    let Some(rt) = runtime() else { return };
    let stats_for = |cfg| {
        let mrt = rt.load_model(MODEL, cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new(MODEL, cfg));
        engine
            .generate(vec![GenRequest::greedy("a short prompt", 2)])
            .unwrap();
        engine.cache_stats()
    };
    let orig = stats_for(ORIGINAL);
    let coopt = stats_for(COOPT);
    assert!(
        coopt.total_writes < orig.total_writes,
        "Opt-KV writes {} < baseline {}",
        coopt.total_writes,
        orig.total_writes
    );
    assert!(coopt.skipped_writes > 0);
    assert_eq!(orig.skipped_writes, 0);
}

#[test]
fn sim_time_orders_configs_like_fig6() {
    let Some(rt) = runtime() else { return };
    let mut total = std::collections::HashMap::new();
    for cfg in [ORIGINAL, COOPT] {
        let mrt = rt.load_model("llama-13b-sim", cfg).unwrap();
        let mut engine = Engine::new(mrt, EngineConfig::new("llama-13b-sim", cfg));
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(format!("prompt {i} {}", "x".repeat(30)), 8))
            .collect();
        engine.generate(reqs).unwrap();
        total.insert(
            cfg.name,
            engine.metrics.sim_prefill_s + engine.metrics.sim_decode_s,
        );
    }
    assert!(
        total["coopt"] < total["original"],
        "coopt {:?} < original {:?}",
        total["coopt"],
        total["original"]
    );
}

// ---------------------------------------------------------------------------
// chunked prefill (Opt-Pa step 1) — mock backend, no artifacts needed
// ---------------------------------------------------------------------------

/// A prompt longer than the per-step token budget is undeliverable in
/// one-shot mode (the engine fails loudly instead of hanging) and
/// completes once chunked prefill splits it across steps.
#[test]
fn long_prompt_admission_needs_chunked_prefill() {
    let long: Vec<u32> = (0..100).map(|i| 33 + (i % 90)).collect();

    // one-shot, step budget 32 < prompt: admission is impossible
    let be = MockBackend::new().with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_step_budget(32);
    let mut e = Engine::new(be, cfg);
    e.submit_tokens(long.clone(), 4, SamplingParams::default(), false)
        .unwrap();
    let err = e.run_to_completion().unwrap_err().to_string();
    assert!(err.contains("stuck"), "unexpected error: {err}");

    // same budget with chunking: the prompt lands window by window
    let be = MockBackend::new().with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_step_budget(32)
        .with_chunked_prefill(16);
    let mut e = Engine::new(be, cfg);
    e.submit_tokens(long, 4, SamplingParams::default(), false)
        .unwrap();
    let results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].generated_tokens, 4);
    assert!(e.metrics.prefill_chunks >= 7, "chunks: {}", e.metrics.prefill_chunks);
    assert_eq!(e.cache_stats().blocks_used, 0);
}

/// A partially-prefilled prompt resumes from its committed offset across
/// steps (never restarting at zero) while decode streams keep running.
#[test]
fn partial_prefill_resumes_from_committed_offset() {
    let be = MockBackend::new().with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_step_budget(24)
        .with_chunked_prefill(16);
    let mut e = Engine::new(be, cfg);
    // streams short enough (3 tokens) that they always land as a single
    // window — every multi-window trace entry below belongs to the long
    // prompt
    for i in 0..3 {
        e.submit(GenRequest::greedy(format!("s{i}"), 16)).unwrap();
    }
    let long: Vec<u32> = (0..96).map(|i| 40 + (i % 80)).collect();
    let long_id = e
        .submit_tokens(long, 3, SamplingParams::default(), false)
        .unwrap();
    let results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), 4);
    let long_result = results.iter().find(|r| r.id == long_id).unwrap();
    assert_eq!(long_result.generated_tokens, 3);

    // the long prompt's windows: strictly increasing offsets, each
    // resuming exactly where the previous ended — no restarts
    let long_windows: Vec<(i32, i32)> = e
        .backend
        .chunk_trace
        .iter()
        .copied()
        .filter(|&(o, l)| o > 0 || l >= 10)
        .collect();
    assert!(long_windows.len() >= 4, "windows: {:?}", e.backend.chunk_trace);
    let mut expect = long_windows[0].0;
    for &(off, len) in &long_windows {
        assert_eq!(off, expect, "window resumed from committed offset");
        expect = off + len;
    }
    assert_eq!(expect, 96, "prefill completed exactly at the prompt length");
    assert_eq!(e.cache_stats().blocks_used, 0);
}

/// Pool pressure mid-prefill: preemption by recompute recovers and every
/// request still completes with a clean pool.
#[test]
fn preempted_partial_prefill_recovers() {
    let geometry = CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: 14,
        max_batch: 4,
        max_seq: 48,
    };
    let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_step_budget(16)
        .with_chunked_prefill(8);
    let mut e = Engine::new(be, cfg);
    for i in 0..2 {
        e.submit(GenRequest::greedy(format!("ss {i}"), 12)).unwrap();
    }
    let long: Vec<u32> = (0..32).map(|i| 40 + (i % 80)).collect();
    e.submit_tokens(long, 2, SamplingParams::default(), false)
        .unwrap();
    let results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(r.generated_tokens >= 1, "every request makes progress");
    }
    assert_eq!(e.cache_stats().blocks_used, 0, "no leaked blocks after preemption");
}

/// Acceptance: with a prompt ≥ 4x the chunk budget running alongside 4
/// decode streams, chunked prefill lowers the p95 (and worst-case)
/// simulated decode inter-token latency vs the one-shot baseline.
#[test]
fn chunked_prefill_lowers_p95_decode_itl() {
    let rows = run_chunk_compare(16, 3, 4, 24).unwrap();
    let one = rows.iter().find(|r| r.mode == "oneshot").unwrap();
    let chk = rows.iter().find(|r| r.mode == "chunked").unwrap();
    assert_eq!(one.tokens, chk.tokens, "same generated workload");
    assert!(chk.prefill_chunks >= 3 * 4, "long prompts actually chunked");
    assert!(
        chk.itl_sim_p95_s < one.itl_sim_p95_s,
        "p95 itl: chunked {} vs one-shot {}",
        chk.itl_sim_p95_s,
        one.itl_sim_p95_s
    );
    assert!(
        chk.itl_sim_max_s < one.itl_sim_max_s,
        "max itl: chunked {} vs one-shot {}",
        chk.itl_sim_max_s,
        one.itl_sim_max_s
    );
}
