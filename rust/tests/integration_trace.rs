//! Request-lifecycle tracing end to end on the deterministic mock
//! backend (no artifacts needed): per-phase latency attribution must
//! reconcile exactly with measured E2E latency — no gap, no double
//! count — through preemption, two-tier KV swap, and cross-replica
//! migration; the flight recorder must return the complete timeline;
//! and the serving endpoints (`/admin/trace`, correlation ids,
//! Prometheus exposition) must surface all of it over HTTP.

use std::sync::atomic::Ordering;

use llm_coopt::config::{CacheGeometry, EngineConfig, ReplicaRole, SwapPolicy, COOPT};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::server::{Client, EngineHandle, Server};
use llm_coopt::util::json::{Object, Value};

/// Wall-phase reconciliation tolerance: spans telescope exactly by
/// construction, so the only slack is f64 addition rounding.
const EPS: f64 = 1e-6;

fn phase_sum(phases: &Value) -> f64 {
    [
        "queue_s",
        "prefill_s",
        "decode_s",
        "swap_blocked_s",
        "migration_s",
    ]
    .iter()
    .map(|k| phases.req_f64(k).unwrap())
    .sum()
}

fn tiered_engine(pool: usize, host: usize) -> Engine<MockBackend> {
    let geometry = CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: pool,
        max_batch: 4,
        max_seq: 48,
    };
    let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
    let cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(host)
        .with_swap_policy(SwapPolicy::Always);
    Engine::new(be, cfg)
}

fn pressure_reqs() -> Vec<GenRequest> {
    (0..6)
        .map(|i| GenRequest::greedy(format!("pp{i} {}", "y".repeat(16)), 12))
        .collect()
}

/// A workload under pool pressure: every request's wall phases
/// partition its E2E latency exactly, swapped victims show up as
/// swap-blocked seconds, and the flight recorder holds a complete
/// timeline for a preempted + swapped request.
#[test]
fn swap_preempted_phases_reconcile_with_e2e() {
    let mut e = tiered_engine(12, 64);
    let results = e.generate(pressure_reqs()).unwrap();
    assert_eq!(results.len(), 6);
    assert!(e.metrics.swap_outs > 0, "pool pressure must swap");

    let mut totals = [0.0f64; 5];
    for r in &results {
        let gap = (r.phases.phase_sum_s() - r.latency_s).abs();
        assert!(
            gap < EPS,
            "request {} phase sum {} != e2e {} (gap {gap})",
            r.id,
            r.phases.phase_sum_s(),
            r.latency_s
        );
        assert!((r.phases.e2e_s - r.latency_s).abs() < EPS);
        totals[0] += r.phases.queue_s;
        totals[1] += r.phases.prefill_s;
        totals[2] += r.phases.decode_s;
        totals[3] += r.phases.swap_blocked_s;
        totals[4] += r.phases.migration_s;
    }
    assert!(
        results.iter().any(|r| r.phases.swap_blocked_s > 0.0),
        "a swapped victim must accumulate swap-blocked wall time"
    );

    // engine-level phase accumulators are exactly the per-request sums
    let m = e.stats_json();
    for (key, want) in [
        ("phase_queue_s", totals[0]),
        ("phase_prefill_s", totals[1]),
        ("phase_decode_s", totals[2]),
        ("phase_swap_blocked_s", totals[3]),
        ("phase_migration_s", totals[4]),
    ] {
        let got = m.req_f64(key).unwrap();
        assert!((got - want).abs() < EPS, "{key}: {got} != {want}");
    }
    // mergeable latency histograms ride along in /metrics
    let hist = m.req("hist").unwrap();
    for key in ["ttft_wall", "e2e_wall", "queue_wall"] {
        assert_eq!(
            hist.req(key).unwrap().req_usize("count").unwrap(),
            6,
            "{key} counts every finished request"
        );
    }
    assert!(hist.req("itl_sim").unwrap().req_usize("count").unwrap() > 0);

    // the flight recorder holds all six finished timelines; the
    // preempted + swapped one is complete: swap_out/swap_in events and
    // phases that sum to its recorded e2e
    let dump = e.trace_json(None, None);
    let entries = dump.as_array().unwrap();
    assert_eq!(entries.len(), 6);
    let mut saw_swapped = false;
    for t in entries {
        let phases = t.req("phases").unwrap();
        assert!((phase_sum(phases) - phases.req_f64("e2e_s").unwrap()).abs() < EPS);
        let labels: Vec<&str> = t
            .req_array("events")
            .unwrap()
            .iter()
            .map(|ev| ev.req_str("label").unwrap())
            .collect();
        assert_eq!(labels.first(), Some(&"queued"));
        assert_eq!(labels.last(), Some(&"finished"));
        assert!(labels.contains(&"admitted"));
        if t.req_usize("preemptions").unwrap() > 0
            && phases.req_f64("swap_blocked_s").unwrap() > 0.0
        {
            saw_swapped = true;
            assert!(labels.contains(&"swap_out"));
            assert!(
                labels.contains(&"swap_in") || labels.contains(&"swap_in_demand"),
                "swapped victim resumed: {labels:?}"
            );
        }
    }
    assert!(saw_swapped, "no preempted+swapped timeline in the recorder");

    // id filtering narrows the dump to one request
    let one = e.trace_json(Some(results[0].id), None);
    assert_eq!(one.as_array().unwrap().len(), 1);
}

/// A sequence handed off between replicas (PD disaggregation) carries
/// its trace: migration wall time lands in the breakdown, the phases
/// still partition E2E across both engines, and the destination's
/// flight recorder serves lookups by engine id and correlation id.
#[test]
fn migrated_request_timeline_is_complete_and_reconciles() {
    let src_cfg = EngineConfig::new("llama-7b-sim", COOPT)
        .with_host_pool(64)
        .with_swap_policy(SwapPolicy::Always)
        .with_role(ReplicaRole::Prefill);
    let mut src = Engine::new(MockBackend::new().with_opt(COOPT), src_cfg);
    let dst_cfg = EngineConfig::new("llama-7b-sim", COOPT).with_role(ReplicaRole::Decode);
    let mut dst = Engine::new(MockBackend::new().with_opt(COOPT), dst_cfg);

    let mut req = GenRequest::greedy(format!("migrate me {}", "m".repeat(40)), 4);
    req.corr_id = Some("tenant-7/job-3".to_string());
    src.submit(req).unwrap();

    // drive the prefill replica until the sequence parks, then hand it
    // off — the trace travels inside the hand-off envelope
    let mut moved = Vec::new();
    for _ in 0..200 {
        src.step().unwrap();
        for id in src.take_handoff_ready() {
            let h = src.make_handoff(id).unwrap();
            moved.push(dst.migrate_in_seq(h).unwrap());
        }
        if !moved.is_empty() {
            break;
        }
    }
    assert_eq!(moved.len(), 1, "hand-off never surfaced");
    assert_eq!(src.num_pending(), 0);

    let results = dst.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert_eq!(r.id, moved[0]);
    assert_eq!(r.corr_id.as_deref(), Some("tenant-7/job-3"));
    assert!(
        r.phases.migration_s > 0.0,
        "hand-off transit must land in the migration phase"
    );
    assert!((r.phases.phase_sum_s() - r.latency_s).abs() < EPS);

    // the request finished on the destination, so only its recorder
    // holds the timeline — and the timeline spans both engines
    assert!(src.trace_json(None, None).as_array().unwrap().is_empty());
    for dump in [
        dst.trace_json(Some(r.id), None),
        dst.trace_json(None, Some("tenant-7/job-3")),
    ] {
        let entries = dump.as_array().unwrap();
        assert_eq!(entries.len(), 1);
        let labels: Vec<&str> = entries[0]
            .req_array("events")
            .unwrap()
            .iter()
            .map(|ev| ev.req_str("label").unwrap())
            .collect();
        for want in ["queued", "admitted", "migrate_park", "migrate_out", "migrate_in", "finished"]
        {
            assert!(labels.contains(&want), "missing {want} in {labels:?}");
        }
    }
    // a non-matching filter returns an empty dump, not an error
    assert!(dst
        .trace_json(None, Some("nobody"))
        .as_array()
        .unwrap()
        .is_empty());
}

/// `--trace-sample 0` keeps phase attribution (always on) but drops the
/// event timeline; `--trace-depth 0` disables the recorder entirely.
#[test]
fn trace_knobs_gate_events_and_recorder() {
    let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_trace_sample(0.0);
    let mut e = Engine::new(MockBackend::new().with_opt(COOPT), cfg);
    let results = e
        .generate(vec![GenRequest::greedy("unsampled", 4)])
        .unwrap();
    assert!((results[0].phases.phase_sum_s() - results[0].latency_s).abs() < EPS);
    assert!(results[0].phases.e2e_s > 0.0, "phase accounting stays on");
    let dump = e.trace_json(None, None);
    let entries = dump.as_array().unwrap();
    assert_eq!(entries.len(), 1, "recorder still records the breakdown");
    assert!(
        entries[0].req_array("events").unwrap().is_empty(),
        "unsampled request carries no event timeline"
    );

    let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_trace_depth(0);
    let mut e = Engine::new(MockBackend::new().with_opt(COOPT), cfg);
    e.generate(vec![GenRequest::greedy("unrecorded", 4)]).unwrap();
    assert!(e.trace_json(None, None).as_array().unwrap().is_empty());
}

/// The serving surface: correlation ids round-trip `/v1/generate`, the
/// response carries the phase breakdown, `/admin/trace` serves filtered
/// flight-recorder dumps, and `/metrics?format=prometheus` renders the
/// merged histograms as text exposition.
#[test]
fn http_trace_endpoints_and_prometheus_exposition() {
    let engine = Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT));
    let handle = EngineHandle::spawn(engine);
    let server = Server::bind("127.0.0.1:0", handle, 4).unwrap();
    let client = Client::new(server.addr.to_string());
    let stop = server.stop_flag();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let mut req = Object::new();
    req.insert("prompt", "trace me over http");
    req.insert("max_new_tokens", 4usize);
    req.insert("correlation_id", "tenant-42/req-7");
    let (code, v) = client.post("/v1/generate", &Value::Object(req)).unwrap();
    assert_eq!(code, 200);
    assert_eq!(v.req_str("correlation_id").unwrap(), "tenant-42/req-7");
    let id = v.req_usize("id").unwrap();
    let phases = v.req("phases").unwrap();
    assert!((phase_sum(phases) - phases.req_f64("e2e_s").unwrap()).abs() < EPS);
    assert!((phases.req_f64("e2e_s").unwrap() - v.req_f64("latency_s").unwrap()).abs() < EPS);

    // flight-recorder lookups by correlation id and by engine id
    let (code, t) = client.get("/admin/trace?corr=tenant-42/req-7").unwrap();
    assert_eq!(code, 200);
    let reqs = t.req_array("replicas").unwrap()[0].req_array("requests").unwrap().to_vec();
    assert_eq!(reqs.len(), 1);
    assert_eq!(reqs[0].req_usize("id").unwrap(), id);
    assert_eq!(reqs[0].req_str("corr_id").unwrap(), "tenant-42/req-7");
    let (_, t) = client.get(&format!("/admin/trace?id={id}")).unwrap();
    assert_eq!(
        t.req_array("replicas").unwrap()[0]
            .req_array("requests")
            .unwrap()
            .len(),
        1
    );
    // a malformed id filter is a client error, not a silent full dump
    let (code, _) = client.get("/admin/trace?id=xyz").unwrap();
    assert_eq!(code, 400);

    // Prometheus text exposition (polled: the snapshot publishes after
    // the engine's next step)
    let mut text = String::new();
    for _ in 0..100 {
        let (code, body) = client.get_text("/metrics?format=prometheus").unwrap();
        assert_eq!(code, 200);
        if body.contains("llm_coopt_e2e_wall_seconds_count 1") {
            text = body;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(text.contains("# TYPE llm_coopt_tokens_generated gauge"));
    assert!(text.contains("# TYPE llm_coopt_e2e_wall_seconds histogram"));
    assert!(text.contains("llm_coopt_e2e_wall_seconds_bucket{le=\"+Inf\"} 1"));
    assert!(text.contains("llm_coopt_phase_decode_s"));
    // the JSON form still serves at the bare path
    let (code, m) = client.get("/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(m.get("hist").is_some());

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap();
}
