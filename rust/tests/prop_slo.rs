//! Property + acceptance tests for SLO-aware overload control.
//!
//! The contract under test: **overload control changes who gets served,
//! never what the served get back.**  Under admission control, deadline
//! enforcement, and class-aware scheduling, every request that completes
//! normally is token-identical to an unconstrained single-engine
//! reference; a deadline-cancelled request returns a strict prefix of
//! its reference output.  Shedding obeys the priority contract — no
//! interactive request is ever refused while queued batch work could be
//! displaced instead — and neither shedding nor cancellation leaks a
//! device block or a host slot.

use llm_coopt::config::{
    EngineConfig, ReqClass, RouterPolicy, SloConfig, COOPT,
};
use llm_coopt::coordinator::{Engine, FinishReason, GenRequest};
use llm_coopt::router::{Router, SHED_MARKER};
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::util::quickprop::{check, gens};

fn mock_engine() -> Engine<MockBackend> {
    Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    )
}

fn slo_engine(slo: &SloConfig) -> Engine<MockBackend> {
    Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT)
            .with_slo_admission(true)
            .with_interactive_ttft_ms(slo.interactive_ttft_ms)
            .with_interactive_prefill_reserve(slo.interactive_prefill_reserve),
    )
}

/// The class mix for one generated request: interleaves both priority
/// lanes, tenant tags (exercising the share cap), and a doomed
/// deadline-0 batch request (expired on arrival, cancelled at the first
/// step boundary — the deterministic deadline path).
fn class_for(p: usize, i: usize) -> ReqClass {
    match (p + i) % 6 {
        0 => ReqClass::interactive().with_deadline_ms(60_000),
        1 => ReqClass::interactive(),
        2 => ReqClass::batch().with_deadline_ms(0),
        3 => ReqClass::batch().with_tenant(format!("t{}", p % 3)),
        4 => ReqClass::batch()
            .with_tenant(format!("t{}", p % 3))
            .with_deadline_ms(120_000),
        _ => ReqClass::batch(),
    }
}

/// Property: 120 random overloaded traces, each replayed through a
/// SLO-controlled router (varying policy, replica count, queue bound,
/// TTFT budget, prefill reserve, and arrival pacing) against its
/// unconstrained single-engine reference.  Checks, per case:
///
/// (a) every admitted request that finishes normally is token-identical
///     to the reference, and every deadline-cancelled request returned
///     a prefix of its reference tokens;
/// (b) no interactive request is shed while the batch queue is nonzero
///     (batch is always the preferred victim);
/// (c) offered = completed + shed (nothing lost, nothing duplicated),
///     and after the run every replica's device pool and host tier
///     drain to zero — shed and cancelled requests leak nothing.
#[test]
fn overload_control_preserves_outputs_and_leaks_nothing() {
    check(
        120,
        gens::pair(gens::vec(gens::usize_to(23), 3..=12), gens::usize_to(1000)),
        |&(ref profile, seed): &(Vec<usize>, usize)| {
            let n = profile.len();
            // the index rides in the correlation id: shed requests never
            // produce a result, so positional alignment cannot work
            let plain: Vec<GenRequest> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| {
                    let tenant = p % 3;
                    let mut req = GenRequest::greedy(
                        format!(
                            "tenantslo{tenant} {} tail {seed} {i} {}",
                            "s".repeat(18 + 2 * tenant),
                            "y".repeat(p)
                        ),
                        2 + (p + seed) % 6,
                    );
                    req.corr_id = Some(format!("slo/{i}"));
                    req
                })
                .collect();
            let classes: Vec<ReqClass> = profile
                .iter()
                .enumerate()
                .map(|(i, &p)| class_for(p, i))
                .collect();
            // token-identity reference: one unconstrained engine, untagged
            let mut single = mock_engine();
            let base = single.generate(plain.clone()).unwrap();

            let slo = SloConfig {
                admission: true,
                // slack budget sheds on the queue bound and tenant share
                // only; the 1 ms budget exercises the projected-wait rules
                // for both classes
                interactive_ttft_ms: if seed % 2 == 0 { 50_000 } else { 1 },
                interactive_prefill_reserve: if seed % 3 == 0 { 0.5 } else { 0.0 },
                tenant_share: 0.6,
                max_batch_queue: seed % 4,
            };
            let policy = RouterPolicy::ALL[seed % RouterPolicy::ALL.len()];
            let nrep = 1 + (seed / 7) % 2;
            let steps_per_arrival = (seed / 3) % 3;

            let engines: Vec<Engine<MockBackend>> =
                (0..nrep).map(|_| slo_engine(&slo)).collect();
            let mut router = Router::new(engines, policy).with_slo(slo);
            let mut shed = vec![false; n];
            for (i, req) in plain.iter().enumerate() {
                match router.submit(req.clone().with_class(classes[i].clone())) {
                    Ok((replica, _)) => {
                        if replica >= nrep {
                            return false;
                        }
                    }
                    Err(e) if e.to_string().starts_with(SHED_MARKER) => {
                        // (b) batch is always the preferred victim: an
                        // interactive shed requires an empty batch queue
                        if classes[i].priority.is_interactive()
                            && router.batch_queue_depth() != 0
                        {
                            return false;
                        }
                        shed[i] = true;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                for _ in 0..steps_per_arrival {
                    router.step_all().unwrap();
                }
            }
            let results = router.run_to_completion().unwrap();
            // (c) conservation: offered = completed + shed
            if results.len() + shed.iter().filter(|&&s| s).count() != n {
                return false;
            }
            let mut seen = vec![false; n];
            for r in &results {
                let idx = r
                    .result
                    .corr_id
                    .as_deref()
                    .and_then(|c| c.strip_prefix("slo/"))
                    .and_then(|s| s.parse::<usize>().ok())
                    .expect("result lost its slo/<i> correlation id");
                if shed[idx] || seen[idx] {
                    return false; // shed requests never complete; no dups
                }
                seen[idx] = true;
                // (a) identity: exact for normal finishes, reference
                // prefix for deadline cancellations
                let ok = match r.result.finish {
                    FinishReason::DeadlineExceeded => {
                        base[idx].tokens.starts_with(&r.result.tokens)
                    }
                    _ => {
                        r.result.tokens == base[idx].tokens
                            && r.result.finish == base[idx].finish
                    }
                };
                if !ok {
                    return false;
                }
            }
            if router.shed_requests() != shed.iter().filter(|&&s| s).count() as u64 {
                return false;
            }
            // (c) nothing leaked: device pool and host tier drain to zero
            router.replicas().iter().all(|e| {
                e.cache_stats().blocks_used == 0
                    && e.tier_stats().host_used_blocks == 0
            })
        },
    );
}

/// Acceptance: at 4x the batch-queue bound, the burst's overflow batch
/// work is shed while every interactive request in the same burst is
/// admitted past the full queue.
#[test]
fn burst_sheds_batch_overflow_but_admits_interactive() {
    let slo = SloConfig {
        admission: true,
        interactive_ttft_ms: 50_000,
        interactive_prefill_reserve: 0.0,
        tenant_share: 1.0,
        max_batch_queue: 2,
    };
    let mut router =
        Router::new(vec![mock_engine()], RouterPolicy::LeastLoaded).with_slo(slo);
    let mut batch_shed = 0;
    for i in 0..8 {
        let req = GenRequest::greedy(format!("burst batch {i} load"), 4)
            .with_class(ReqClass::batch());
        match router.submit(req) {
            Ok(_) => {}
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.starts_with(SHED_MARKER), "not a shed: {msg}");
                assert!(msg.contains("batch queue full"), "wrong reason: {msg}");
                assert!(msg.contains("class=batch"), "class echo missing: {msg}");
                batch_shed += 1;
            }
        }
    }
    assert_eq!(batch_shed, 6, "queue bound 2 admits exactly two of eight");
    // interactive jumps the full batch queue without being shed
    for i in 0..3 {
        router
            .submit(
                GenRequest::greedy(format!("urgent {i}"), 2)
                    .with_class(ReqClass::interactive()),
            )
            .unwrap();
    }
    assert_eq!(router.shed_requests(), 6);
    assert_eq!(router.batch_queue_depth(), 2);
    let results = router.run_to_completion().unwrap();
    assert_eq!(results.len(), 5, "2 admitted batch + 3 interactive");
    assert_eq!(router.batch_queue_depth(), 0, "books settle at completion");
    for e in router.replicas() {
        assert_eq!(e.cache_stats().blocks_used, 0);
        assert_eq!(e.tier_stats().host_used_blocks, 0);
    }
}

/// Acceptance: interactive is shed only as a last resort — when the
/// projected wait blows its own TTFT budget *and* no queued batch work
/// is left to displace — and admission recovers once the backlog drains.
#[test]
fn interactive_sheds_only_as_last_resort_and_recovers() {
    let slo = SloConfig {
        admission: true,
        interactive_ttft_ms: 1000,
        interactive_prefill_reserve: 0.0,
        tenant_share: 1.0,
        max_batch_queue: 8,
    };
    let mut router =
        Router::new(vec![mock_engine()], RouterPolicy::LeastLoaded).with_slo(slo);
    // an idle replica admits interactive work unconditionally; this one
    // is heavy enough (cost ≈ 80 + 5·100 tokens ⇒ projected wait well
    // over the 1000 ms budget) to put the cluster over budget by itself
    router
        .submit(
            GenRequest::greedy("warm ".repeat(80), 100)
                .with_class(ReqClass::interactive()),
        )
        .unwrap();
    // over budget with no batch queued: the last-resort rule fires
    let e = router
        .submit(GenRequest::greedy("too late", 2).with_class(ReqClass::interactive()))
        .unwrap_err();
    let msg = e.to_string();
    assert!(msg.starts_with(SHED_MARKER), "not a shed: {msg}");
    assert!(msg.contains("no batch to displace"), "wrong reason: {msg}");
    assert!(msg.contains("class=interactive"), "class echo missing: {msg}");
    // batch is refused for the same backlog, with its own reason
    let e = router
        .submit(GenRequest::greedy("batch too", 2).with_class(ReqClass::batch()))
        .unwrap_err();
    assert!(e.to_string().contains("TTFT budget"), "wrong reason: {e}");
    assert_eq!(router.shed_requests(), 2);
    let results = router.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    // the backlog has drained: interactive admission recovers
    router
        .submit(GenRequest::greedy("recovered", 2).with_class(ReqClass::interactive()))
        .unwrap();
    assert_eq!(router.run_to_completion().unwrap().len(), 1);
    for e in router.replicas() {
        assert_eq!(e.cache_stats().blocks_used, 0);
        assert_eq!(e.tier_stats().host_used_blocks, 0);
    }
}
