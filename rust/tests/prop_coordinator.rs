//! Property tests on the coordinator/cache/scheduler invariants (the
//! quickprop substrate replaces proptest — DESIGN.md).
//!
//! Invariants checked on randomized workloads (prompt lengths, max-new
//! counts, pool sizes):
//!   1. conservation: every allocated block is freed by the end;
//!   2. no sequence loses tokens: generated == requested unless a finite
//!      finish reason says otherwise;
//!   3. slot mappings never collide between live sequences within a step
//!      (checked by the mock backend's contract);
//!   4. admission never exceeds the pool;
//!   5. fairness: FCFS — a request never finishes after one submitted
//!      later with an identical profile, under serial admission.

use llm_coopt::config::{CacheGeometry, EngineConfig, COOPT, ORIGINAL};
use llm_coopt::coordinator::{Engine, FinishReason, GenRequest};
use llm_coopt::kvcache::CacheManager;
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::sampling::SamplingParams;
use llm_coopt::util::quickprop::{check, gens};
use llm_coopt::util::rng::Rng;

#[test]
fn engine_conserves_blocks_and_tokens() {
    check(
        60,
        gens::vec(gens::usize_to(30), 1..=10),
        |profile: &Vec<usize>| {
            let geometry = CacheGeometry {
                block_size: 4,
                max_blocks: 16,
                num_pool_blocks: 24,
                max_batch: 4,
                max_seq: 48,
            };
            let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
            let mut e = Engine::new(be, EngineConfig::new("llama-7b-sim", COOPT))
                .without_cost_model();
            for (i, &p) in profile.iter().enumerate() {
                let prompt = format!("{}{}", i, "p".repeat(p.max(1)));
                let max_new = 1 + p % 7;
                if e.submit(GenRequest::greedy(prompt, max_new)).is_err() {
                    return true; // oversized prompt rejected is fine
                }
            }
            let results = match e.run_to_completion() {
                Ok(r) => r,
                Err(_) => return false,
            };
            if results.len() != profile.len() {
                return false;
            }
            for r in &results {
                let ok = match r.finish {
                    FinishReason::MaxNewTokens => r.generated_tokens >= 1,
                    FinishReason::Eos
                    | FinishReason::MaxContext
                    | FinishReason::PreemptOverflow => true,
                };
                if !ok {
                    return false;
                }
            }
            e.cache_stats().blocks_used == 0
        },
    );
}

#[test]
fn cache_manager_never_leaks_under_random_ops() {
    check(
        80,
        gens::vec(gens::usize_to(9), 1..=40),
        |ops: &Vec<usize>| {
            let mut cm = CacheManager::new(CacheGeometry {
                block_size: 4,
                max_blocks: 8,
                num_pool_blocks: 16,
                max_batch: 4,
                max_seq: 16,
            });
            let mut rng = Rng::new(ops.len() as u64);
            let mut live: Vec<u64> = Vec::new();
            let mut next = 1u64;
            for &op in ops {
                match op % 3 {
                    0 => {
                        // admit
                        let len = 1 + op % 12;
                        let prompt: Vec<u32> =
                            (0..len).map(|_| rng.below(200) as u32).collect();
                        if cm.can_admit(prompt.len(), &COOPT)
                            && cm.prefill(next, &prompt, &COOPT).is_ok()
                        {
                            live.push(next);
                            next += 1;
                        }
                    }
                    1 => {
                        // decode-append on a random live seq
                        if !live.is_empty() {
                            let id = live[rng.below(live.len())];
                            let _ = cm.append_token(id);
                        }
                    }
                    _ => {
                        // free a random live seq
                        if !live.is_empty() {
                            let id = live.swap_remove(rng.below(live.len()));
                            cm.free_seq(id);
                        }
                    }
                }
                // invariant: used blocks always within pool bounds
                let st = cm.stats();
                if st.blocks_used > st.blocks_total {
                    return false;
                }
            }
            for id in live.drain(..) {
                cm.free_seq(id);
            }
            cm.stats().blocks_used == 0
        },
    );
}

#[test]
fn fcfs_completion_order_for_identical_requests() {
    check(30, gens::usize_to(6), |&n: &usize| {
        let be = MockBackend::new().with_opt(COOPT);
        let mut e =
            Engine::new(be, EngineConfig::new("llama-7b-sim", COOPT)).without_cost_model();
        let k = 2 + n;
        for i in 0..k {
            e.submit(GenRequest::greedy(format!("same prompt {i}"), 4))
                .unwrap();
        }
        let results = e.run_to_completion().unwrap();
        // identical profiles => ids finish in submission order
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        ids == sorted
    });
}

/// Opt-Pa step 1 equivalence: for random prompts, chunk sizes, and step
/// budgets, greedy decoding with chunked prefill produces token-for-token
/// identical output to one-shot prefill, with identical final cache
/// accounting (acceptance: ≥ 100 random cases).
#[test]
fn chunked_prefill_equals_oneshot_greedy() {
    check(
        120,
        gens::pair(
            gens::pair(gens::usize_to(99), gens::usize_to(39)),
            gens::pair(gens::usize_to(64), gens::usize_to(1000)),
        ),
        |&((len0, chunk0), (budget0, seed)): &((usize, usize), (usize, usize))| {
            let long_len = 1 + len0; // 1..=100 prompt tokens
            let chunk = 1 + chunk0; // 1..=40 tokens per window
            let budget = 8 + budget0; // 8..=72 shared step tokens
            let mut rng = Rng::new(seed as u64 ^ 0xC0DE);
            let long: Vec<u32> = (0..long_len).map(|_| 33 + rng.below(200) as u32).collect();
            let streams = seed % 3; // 0..=2 short decode streams alongside
            let stream_toks: Vec<Vec<u32>> = (0..streams)
                .map(|_| (0..1 + rng.below(10)).map(|_| 33 + rng.below(200) as u32).collect())
                .collect();

            let run = |chunked: bool| {
                let be = MockBackend::new().with_opt(COOPT);
                let mut cfg = EngineConfig::new("llama-7b-sim", COOPT);
                if chunked {
                    cfg = cfg.with_chunked_prefill(chunk).with_step_budget(budget);
                }
                let mut e = Engine::new(be, cfg).without_cost_model();
                for t in &stream_toks {
                    e.submit_tokens(t.clone(), 3, SamplingParams::default(), false)
                        .unwrap();
                }
                e.submit_tokens(long.clone(), 5, SamplingParams::default(), false)
                    .unwrap();
                let mut r = e.run_to_completion().unwrap();
                r.sort_by_key(|x| x.id);
                let outs: Vec<Vec<u32>> = r.into_iter().map(|x| x.tokens).collect();
                (outs, e.cache_stats())
            };
            let (base, base_stats) = run(false);
            let (ours, our_stats) = run(true);
            base == ours
                && base_stats.blocks_used == our_stats.blocks_used
                && base_stats.blocks_used == 0
                && base_stats.total_writes == our_stats.total_writes
                && base_stats.prefix_hits == our_stats.prefix_hits
        },
    );
}

/// Cache-level Opt-Pa equivalence: committing a prompt as arbitrary
/// (even unaligned) windows yields the same block counts and write
/// totals as one-shot prefill, for both the SkipSet path and the padded
/// baseline.
#[test]
fn chunked_cache_commit_matches_oneshot() {
    check(
        150,
        gens::pair(gens::pair(gens::usize_to(15), gens::usize_to(6)), gens::usize_to(1000)),
        |&((len0, chunk0), seed): &((usize, usize), usize)| {
            let len = 1 + len0; // 1..=16 (geometry max_seq)
            let chunk = 1 + chunk0; // 1..=7, deliberately misaligned vs bs 4
            let geometry = CacheGeometry {
                block_size: 4,
                max_blocks: 8,
                num_pool_blocks: 32,
                max_batch: 4,
                max_seq: 16,
            };
            let mut rng = Rng::new(seed as u64);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(200) as u32).collect();
            for opt in [COOPT, ORIGINAL] {
                let mut one = CacheManager::new(geometry);
                let p = one.prefill(1, &prompt, &opt).unwrap();
                let mut chunked = CacheManager::new(geometry);
                let mut off = 0;
                let mut written = 0;
                let mut skipped = 0;
                while off < len {
                    let take = chunk.min(len - off);
                    let fin = off + take == len;
                    let c = chunked
                        .prefill_chunk(1, &prompt, off, take, &opt, fin)
                        .unwrap();
                    written += c.written;
                    skipped += c.skipped;
                    off += take;
                }
                if written != p.written
                    || skipped != p.skipped
                    || chunked.seq_len(1) != one.seq_len(1)
                    || chunked.stats().blocks_used != one.stats().blocks_used
                    || chunked.stats().total_writes != one.stats().total_writes
                {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn baseline_padding_always_costs_more_blocks() {
    check(
        60,
        gens::pair(gens::usize_to(14), gens::usize_to(1000)),
        |&(len, seed): &(usize, usize)| {
            let geometry = CacheGeometry {
                block_size: 4,
                max_blocks: 8,
                num_pool_blocks: 32,
                max_batch: 4,
                max_seq: 16,
            };
            let mut rng = Rng::new(seed as u64);
            let prompt: Vec<u32> = (0..len.max(1)).map(|_| rng.below(200) as u32).collect();
            let mut orig = CacheManager::new(geometry);
            let mut coopt = CacheManager::new(geometry);
            let po = orig.prefill(1, &prompt, &ORIGINAL).unwrap();
            let pc = coopt.prefill(1, &prompt, &COOPT).unwrap();
            // Eq. 2/5: baseline writes every padded slot, Opt-KV only real ones
            po.written == geometry.max_seq
                && pc.written == prompt.len()
                && orig.stats().blocks_used >= coopt.stats().blocks_used
        },
    );
}
