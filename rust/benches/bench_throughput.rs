//! **Fig. 7 reproduction**: generation throughput per model (Eq. 12),
//! Original vs each optimization vs LLM-CoOpt, ShareGPT-sim trace.
//!
//! Paper's reported CoOpt throughput gains:
//!   LLaMa-7B 7.20% | LLaMa2-7B 6.13% | LLaMa-13B 12.13% |
//!   LLaMa2-13B 10.85% | LLaMa-Pro-8B 5.72%
//! Key shape: 13B-class gains ~2x the 7B-class (memory-capacity coupling;
//! DESIGN.md), CoOpt >= each individual optimization.
//!
//! Run: cargo bench --bench bench_throughput

use llm_coopt::config::{artifacts_dir, ALL_CONFIGS};
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::util::bench::BenchSuite;
use llm_coopt::util::json::{Object, Value};
use llm_coopt::workload::harness::{gain_pct, run_trace};
use llm_coopt::workload::TraceSpec;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP fig7: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let quick = std::env::var("COOPT_BENCH_QUICK").is_ok();
    let spec = TraceSpec {
        num_requests: if quick { 8 } else { 24 },
        max_new: if quick { 8 } else { 32 },
        seed: 0xF17_7,
        ..Default::default()
    };

    let mut suite = BenchSuite::quick("fig7-throughput");
    println!("Fig. 7 — generation throughput (Eq. 12), ShareGPT-sim x{} requests", spec.num_requests);
    println!(
        "{:<20} {:>10} {:>14} {:>14} {:>10} {:>8}",
        "model", "config", "sim tok/s", "wall tok/s", "Δsim%", "preempt"
    );
    let mut report = Vec::new();
    for model in rt.manifest.model_names() {
        let mut base_sim = 0.0;
        let mut base_wall = 0.0;
        for cfg in ALL_CONFIGS {
            let row = run_trace(&rt, &model, cfg, &spec, true)?;
            if cfg.name == "original" {
                base_sim = row.throughput_sim;
                base_wall = row.throughput_wall;
            }
            let gain = gain_pct(base_sim, row.throughput_sim);
            println!(
                "{:<20} {:>10} {:>12.1}/s {:>12.1}/s {:>9.2}% {:>8}",
                model, cfg.name, row.throughput_sim, row.throughput_wall, gain, row.preemptions
            );
            let mut o = row.to_json();
            if let Value::Object(obj) = &mut o {
                obj.insert("throughput_gain_sim_pct", gain);
                obj.insert(
                    "throughput_gain_wall_pct",
                    gain_pct(base_wall, row.throughput_wall),
                );
            }
            report.push(o);
            suite.record(
                format!("fig7/{model}/{}", cfg.name),
                &[1.0 / row.throughput_sim.max(1e-9)],
                1.0,
            );
        }
        println!();
    }
    let mut top = Object::new();
    top.insert("figure", "fig7");
    top.insert("rows", Value::Array(report));
    std::fs::create_dir_all("target/bench-reports")?;
    std::fs::write(
        "target/bench-reports/fig7.json",
        Value::Object(top).to_string_pretty(),
    )?;
    suite.report();
    suite.write_json()?;
    Ok(())
}
