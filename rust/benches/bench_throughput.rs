//! **Fig. 7 reproduction**: generation throughput per model (Eq. 12),
//! Original vs each optimization vs LLM-CoOpt, ShareGPT-sim trace.
//!
//! Paper's reported CoOpt throughput gains:
//!   LLaMa-7B 7.20% | LLaMa2-7B 6.13% | LLaMa-13B 12.13% |
//!   LLaMa2-13B 10.85% | LLaMa-Pro-8B 5.72%
//! Key shape: 13B-class gains ~2x the 7B-class (memory-capacity coupling;
//! DESIGN.md), CoOpt >= each individual optimization.
//!
//! Also reports the **chunked prefill** (Opt-Pa step 1) throughput deltas
//! on the deterministic mock + Z100 model (runs without artifacts): Eq. 12
//! generation throughput with chunking on vs off under the long-prompt
//! mixed-batch scenario, with chunk counts and inter-chunk stall.
//!
//! Run: cargo bench --bench bench_throughput

use llm_coopt::config::{artifacts_dir, builtin_preset, ALL_CONFIGS, COOPT};
use llm_coopt::platform::{CostModel, SeqCostInput};
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::util::bench::BenchSuite;
use llm_coopt::util::json::{Object, Value};
use llm_coopt::workload::harness::{
    gain_pct, reduction_pct, run_adaptive_spec_compare, run_chunk_compare,
    run_global_prefix_reuse, run_observability_compare, run_pd_compare,
    run_predictive_control, run_router_compare, run_slo_overload, run_spec_compare,
    run_swap_compare, run_trace, write_bench_serve, AdaptiveSpecPoint,
};
use llm_coopt::workload::{MultiTenantSpec, PdTraceSpec, SloMix, TraceSpec};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("COOPT_BENCH_QUICK").is_ok();

    // --- two-tier KV (Opt-KV tier manager): swap-instead-of-recompute
    // throughput win under a pool-exhausting workload (no artifacts)
    println!("tiered KV — Eq. 12 throughput under an undersized pool");
    println!(
        "{:<10} {:>14} {:>14} {:>9} {:>10} {:>10} {:>10}",
        "mode", "sim tok/s", "total lat(s)", "preempt", "swap o/i", "recomp_tok", "tokens"
    );
    let (swap_requests, swap_max_new) = if quick { (6, 12) } else { (8, 24) };
    let swap_rows = run_swap_compare(swap_requests, swap_max_new)?;
    let mut swap_report = Vec::new();
    for r in &swap_rows {
        println!(
            "{:<10} {:>12.1}/s {:>14.4} {:>9} {:>6}/{:<3} {:>10} {:>10}",
            r.mode,
            r.throughput_sim,
            r.latency_sim_s,
            r.preemptions,
            r.swap_outs,
            r.swap_ins,
            r.tokens_recomputed,
            r.tokens
        );
        swap_report.push(r.to_json());
    }
    if let [base, swap] = &swap_rows[..] {
        println!(
            "throughput with the host tier: {:+.1}% (recomputed tokens {} -> {})\n",
            gain_pct(base.throughput_sim, swap.throughput_sim),
            base.tokens_recomputed,
            swap.tokens_recomputed
        );
    }
    write_bench_serve(
        "swap_vs_recompute",
        &swap_report,
        &format!("requests={swap_requests},max_new={swap_max_new}"),
    )?;

    // --- speculative decoding: draft-and-verify multi-token commits
    // (greedy, output-identical by construction; mock + Z100 model)
    println!("speculative decoding — Eq. 12 throughput, draft-and-verify vs one-token decode");
    println!(
        "{:<10} {:>3} {:>14} {:>9} {:>8} {:>8} {:>8}",
        "mode", "k", "sim tok/s", "tok/step", "accept", "rounds", "tokens"
    );
    let (spec_requests, spec_max_new, spec_ks) = (3, if quick { 16 } else { 32 }, [2usize, 4]);
    let spec_rows = run_spec_compare(spec_requests, spec_max_new, &spec_ks)?;
    let mut spec_report = Vec::new();
    for r in &spec_rows {
        println!(
            "{:<10} {:>3} {:>12.1}/s {:>9.2} {:>7.1}% {:>8} {:>8}",
            r.mode,
            r.draft_tokens,
            r.throughput_sim,
            r.tokens_per_step,
            r.acceptance_rate * 100.0,
            r.decode_rounds,
            r.tokens
        );
        spec_report.push(r.to_json());
    }
    if let Some(base) = spec_rows.first() {
        for r in spec_rows.iter().skip(1) {
            println!(
                "k={}: throughput {:+.1}% vs one-token decode ({:.2} tokens/step at {:.0}% acceptance)",
                r.draft_tokens,
                gain_pct(base.throughput_sim, r.throughput_sim),
                r.tokens_per_step,
                r.acceptance_rate * 100.0
            );
        }
    }
    // analytic crossover on the Z100 model: the acceptance rate below
    // which drafting stops paying for itself (weight-stream-bound batch)
    let cm = CostModel::for_preset(&builtin_preset("llama-7b-sim").unwrap(), 16)
        .with_ctx_scale(8.0);
    let cross_seqs: Vec<SeqCostInput> = (0..3)
        .map(|_| SeqCostInput {
            ctx_len: 24,
            allocated_blocks: 2,
        })
        .collect();
    for k in [2usize, 4] {
        match cm.spec_crossover_acceptance(&cross_seqs, &COOPT, k, 0.125) {
            Some(a) => println!(
                "k={k}: speculation beats one-token decode above ≈ {:.0}% acceptance",
                a * 100.0
            ),
            None => println!("k={k}: speculation cannot beat one-token decode at this batch"),
        }
    }
    println!();
    write_bench_serve(
        "speculative_decode",
        &spec_report,
        &format!("requests={spec_requests},max_new={spec_max_new},ks={spec_ks:?}"),
    )?;

    // --- adaptive speculation: fixed-k sweep vs the online controller
    // over (divergence, batch) points where no single fixed k wins
    // everywhere (outputs token-identical by construction)
    println!("adaptive speculation — fixed-k sweep vs online controller");
    println!(
        "{:<12} {:>4} {:>6} {:>14} {:>9} {:>8} {:>7} {:>7}",
        "mode", "div", "batch", "sim tok/s", "tok/step", "accept", "rounds", "k_last"
    );
    let ad_points = [
        // weight-stream-bound lone stream, strong draft: long k wins
        AdaptiveSpecPoint { divergence: 10, batch: 1 },
        // same batch, weak draft (~50% divergence): short k wins
        AdaptiveSpecPoint { divergence: 2, batch: 1 },
        // GEMM-bound batch: only k = 0 wins, whatever the draft
        AdaptiveSpecPoint { divergence: 10, batch: 6 },
    ];
    let (ad_max_new, ad_fixed_ks, ad_k_max) = (if quick { 32 } else { 48 }, [1usize, 2, 4], 4);
    let ad_rows = run_adaptive_spec_compare(&ad_points, ad_max_new, &ad_fixed_ks, ad_k_max)?;
    for r in &ad_rows {
        println!(
            "{:<12} {:>4} {:>6} {:>12.1}/s {:>9.2} {:>7.1}% {:>7} {:>7}",
            r.req_str("mode").unwrap_or("?"),
            r.req_usize("divergence").unwrap_or(0),
            r.req_usize("batch").unwrap_or(0),
            r.req_f64("throughput_sim").unwrap_or(0.0),
            r.req_f64("tokens_per_step").unwrap_or(0.0),
            r.req_f64("acceptance_rate").unwrap_or(0.0) * 100.0,
            r.req_usize("decode_rounds").unwrap_or(0),
            r.get("k_last")
                .and_then(|v| v.as_usize())
                .map(|k| k.to_string())
                .unwrap_or_else(|| "-".into()),
        );
    }
    println!();
    write_bench_serve(
        "adaptive_speculation",
        &ad_rows,
        &format!(
            "points={:?},max_new={ad_max_new},fixed_ks={ad_fixed_ks:?},k_max={ad_k_max}",
            ad_points
                .iter()
                .map(|p| (p.divergence, p.batch))
                .collect::<Vec<_>>()
        ),
    )?;

    // --- multi-replica routing: the same multi-tenant skewed-prefix
    // trace placed across N engines by each policy (outputs asserted
    // token-identical inside the harness; mock + Z100 model)
    println!("multi-replica routing — cluster Eq. 12 throughput + prefix-hit rate");
    println!(
        "{:<16} {:>3} {:>14} {:>11} {:>8} {:>9} {:>6}",
        "policy", "N", "cluster tok/s", "busy max(s)", "spread", "hit rate", "hits"
    );
    let mt_spec = MultiTenantSpec::default();
    let router_counts = [1usize, 2, 4];
    let router_rows = run_router_compare(&router_counts, &mt_spec)?;
    for r in &router_rows {
        println!(
            "{:<16} {:>3} {:>12.1}/s {:>11.4} {:>8.3} {:>8.1}% {:>6}",
            r.req_str("policy")?,
            r.req_usize("replicas")?,
            r.req_f64("cluster_throughput_sim")?,
            r.req_f64("busy_max_s")?,
            r.req_f64("busy_spread")?,
            r.req_f64("prefix_hit_rate")? * 100.0,
            r.req_usize("prefix_hits")?,
        );
    }
    let at = |policy: &str, n: usize| {
        router_rows.iter().find(|r| {
            r.req_str("policy").ok() == Some(policy)
                && r.req_usize("replicas").ok() == Some(n)
        })
    };
    if let (Some(rr), Some(ll), Some(pa)) = (
        at("round_robin", 4),
        at("least_loaded", 4),
        at("prefix_affinity", 4),
    ) {
        println!(
            "N=4: least_loaded {:+.1}% cluster throughput vs round_robin; \
             prefix_affinity hit rate {:.1}% vs {:.1}%\n",
            gain_pct(
                rr.req_f64("cluster_throughput_sim")?,
                ll.req_f64("cluster_throughput_sim")?
            ),
            pa.req_f64("prefix_hit_rate")? * 100.0,
            rr.req_f64("prefix_hit_rate")? * 100.0,
        );
    }
    write_bench_serve(
        "multi_replica_routing",
        &router_rows,
        &format!(
            "requests={},tenants={},zipf_s={},seed={:#x},replicas={router_counts:?}",
            mt_spec.num_requests, mt_spec.tenants, mt_spec.zipf_s, mt_spec.seed
        ),
    )?;

    // --- cluster-wide prefix reuse: the hot-tenant Zipfian trace
    // driven open-loop, prefix_affinity (owner map only) vs directory
    // (global prefix directory + cost-priced cross-replica KV pulls);
    // outputs asserted token-identical inside the harness
    println!("global prefix reuse — directory + cross-replica pulls vs affinity (open loop)");
    println!(
        "{:<16} {:>3} {:>14} {:>9} {:>6} {:>6} {:>9} {:>10} {:>6}",
        "policy", "N", "cluster tok/s", "hit rate", "hits", "pulls", "pull blk", "bytes", "stale"
    );
    let reuse_spec = MultiTenantSpec {
        num_requests: if quick { 40 } else { 64 },
        tenants: 6,
        zipf_s: 1.5,
        system_prompt_min: 47,
        system_prompt_max: 63,
        seed: 0xD1_8ec7,
        ..MultiTenantSpec::default()
    };
    let reuse_counts = [4usize];
    let reuse_rows = run_global_prefix_reuse(&reuse_counts, &reuse_spec)?;
    for r in &reuse_rows {
        println!(
            "{:<16} {:>3} {:>12.1}/s {:>8.1}% {:>6} {:>6} {:>9} {:>10} {:>6}",
            r.req_str("policy")?,
            r.req_usize("replicas")?,
            r.req_f64("cluster_throughput_sim")?,
            r.req_f64("prefix_hit_rate")? * 100.0,
            r.req_usize("prefix_hits")?,
            r.req_usize("prefix_pulls")?,
            r.req_usize("prefix_pull_blocks")?,
            r.req_usize("prefix_pull_bytes")?,
            r.req_usize("prefix_pull_stale")?,
        );
    }
    let reuse_at = |policy: &str| {
        reuse_rows.iter().find(|r| {
            r.req_str("policy").ok() == Some(policy) && r.req_usize("replicas").ok() == Some(4)
        })
    };
    if let (Some(pa), Some(dir)) = (reuse_at("prefix_affinity"), reuse_at("directory")) {
        println!(
            "N=4: directory hit rate {:.1}% vs {:.1}% affinity-only; cluster throughput \
             {:+.1}% ({} blocks pulled over PCIe)\n",
            dir.req_f64("prefix_hit_rate")? * 100.0,
            pa.req_f64("prefix_hit_rate")? * 100.0,
            gain_pct(
                pa.req_f64("cluster_throughput_sim")?,
                dir.req_f64("cluster_throughput_sim")?
            ),
            dir.req_usize("prefix_pull_blocks")?,
        );
    }
    write_bench_serve(
        "global_prefix_reuse",
        &reuse_rows,
        &format!(
            "requests={},tenants={},zipf_s={},seed={:#x},replicas={reuse_counts:?}",
            reuse_spec.num_requests, reuse_spec.tenants, reuse_spec.zipf_s, reuse_spec.seed
        ),
    )?;

    // --- disaggregated prefill/decode: the bursty long-prefill +
    // steady-decode trace on a 4-replica cluster, PD-split (KV hand-off
    // through the host tier) vs all-mixed (outputs asserted
    // token-identical inside the harness; mock + Z100 model)
    println!("disaggregated PD — decode ITL under bursty prefill, PD-split vs mixed (N=4)");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>8} {:>10} {:>10}",
        "mode", "itl p50(s)", "itl p95(s)", "cluster tok/s", "mig o/i", "mig bytes", "recomp_tok"
    );
    let pd_spec = PdTraceSpec::default();
    let pd_rows = run_pd_compare(&pd_spec)?;
    for r in &pd_rows {
        println!(
            "{:<10} {:>12.5} {:>12.5} {:>12.1}/s {:>4}/{:<3} {:>10} {:>10}",
            r.req_str("mode")?,
            r.req_f64("decode_itl_sim_p50_s")?,
            r.req_f64("decode_itl_sim_p95_s")?,
            r.req_f64("cluster_throughput_sim")?,
            r.req_usize("migrations_out")?,
            r.req_usize("migrations_in")?,
            r.req_usize("migration_bytes")?,
            r.req_usize("tokens_recomputed")?,
        );
    }
    if let [pd, mixed] = &pd_rows[..] {
        println!(
            "decode ITL p95 reduction with the PD split: {:.1}% ({} blocks over PCIe, \
             {} tokens re-prefilled)\n",
            reduction_pct(
                mixed.req_f64("decode_itl_sim_p95_s")?,
                pd.req_f64("decode_itl_sim_p95_s")?
            ),
            pd.req_usize("migrated_blocks")?,
            pd.req_usize("tokens_recomputed")?,
        );
    }
    write_bench_serve(
        "disaggregated_pd",
        &pd_rows,
        &format!(
            "requests={},burst_frac={},burst_size={},burst_new={},seed={:#x},replicas=4",
            pd_spec.num_requests, pd_spec.burst_frac, pd_spec.burst_size, pd_spec.burst_new,
            pd_spec.seed
        ),
    )?;

    // --- observability: tracing overhead on the multi-tenant Zipfian
    // trace — flight recorder + full event sampling vs tracing off
    // (outputs asserted token-identical inside the harness; the sim
    // clock never prices trace bookkeeping, so the Eq. 12 ratio is 1.0)
    println!("observability — tracing overhead, traced (depth=64, sample=1.0) vs untraced");
    println!(
        "{:<10} {:>14} {:>10} {:>8} {:>22}",
        "mode", "sim tok/s", "busy(s)", "tokens", "phase reconcile err(s)"
    );
    let obs_rows = run_observability_compare(&mt_spec)?;
    for r in &obs_rows {
        println!(
            "{:<10} {:>12.1}/s {:>10.4} {:>8} {:>22.3e}",
            r.req_str("mode")?,
            r.req_f64("throughput_sim")?,
            r.req_f64("busy_s")?,
            r.req_usize("tokens")?,
            r.req_f64("phase_reconcile_max_err_s")?,
        );
    }
    let traced = &obs_rows[0];
    println!(
        "Eq. 12 sim-throughput ratio traced/untraced: {:.4} (gate >= 0.97); \
         chrome trace -> {}\n",
        traced.req_f64("sim_throughput_ratio")?,
        traced
            .get("chrome_trace_path")
            .and_then(Value::as_str)
            .unwrap_or("-"),
    );
    write_bench_serve(
        "observability",
        &obs_rows,
        &format!(
            "requests={},tenants={},zipf_s={},seed={:#x},depths=[64,0],samples=[1.0,0.0]",
            mt_spec.num_requests, mt_spec.tenants, mt_spec.zipf_s, mt_spec.seed
        ),
    )?;

    // --- SLO overload control: the 1:3 interactive:batch multi-tenant
    // trace at ~2x capacity on an undersized replica, admission +
    // priority scheduling + deadline enforcement on vs the untagged
    // FIFO baseline (served outputs asserted token-identical to an
    // unconstrained reference inside the harness)
    println!("SLO overload — per-class tails at ~2x capacity, control on vs off");
    println!(
        "{:<8} {:>14} {:>13} {:>13} {:>6} {:>7} {:>8} {:>8}",
        "mode", "int ttft p99", "int itl p95", "batch e2e p95", "shed", "expired", "preempt",
        "tokens"
    );
    let slo_mix = SloMix::default();
    let slo_rows = run_slo_overload(&mt_spec, &slo_mix)?;
    for r in &slo_rows {
        println!(
            "{:<8} {:>13.4}s {:>12.5}s {:>12.4}s {:>6} {:>7} {:>8} {:>8}",
            r.req_str("mode")?,
            r.req_f64("interactive_ttft_wall_p99_s")?,
            r.req_f64("interactive_itl_wall_p95_s")?,
            r.req_f64("batch_e2e_wall_p95_s")?,
            r.req_usize("shed_requests")?,
            r.req_usize("deadline_cancellations")?,
            r.req_usize("preemptions")?,
            r.req_usize("tokens")?,
        );
    }
    if let [on, off] = &slo_rows[..] {
        println!(
            "interactive TTFT p99 reduction with control on: {:.1}% \
             ({} batch shed, {} expired cancelled; batch completed {}/{})\n",
            reduction_pct(
                off.req_f64("interactive_ttft_wall_p99_s")?,
                on.req_f64("interactive_ttft_wall_p99_s")?
            ),
            on.req_usize("batch_shed")?,
            on.req_usize("deadline_cancellations")?,
            on.req_usize("batch_completed")?,
            on.req_usize("batch_offered")?,
        );
    }
    write_bench_serve(
        "slo_overload",
        &slo_rows,
        &format!(
            "requests={},tenants={},zipf_s={},seed={:#x},mix=1:{},expired_head={},replicas=1",
            mt_spec.num_requests,
            mt_spec.tenants,
            mt_spec.zipf_s,
            mt_spec.seed,
            slo_mix.interactive_every - 1,
            slo_mix.expired_head
        ),
    )?;

    // --- predictive control: the bursty Zipfian multi-tenant trace at
    // N=2 undersized replicas, the predictive plane (burst-scored
    // admission pre-tightening, per-tenant length hints, self-scored
    // wait quotes) on vs off over identical offered work and admission
    // knobs (token identity vs an unconstrained reference asserted
    // inside the harness; tails reported over the post-warm-up window
    // where the detector has scored enough bursts to act)
    println!("predictive control — bursty trace at N=2, forecast on vs off");
    println!(
        "{:<13} {:>15} {:>14} {:>12} {:>6} {:>8} {:>8}",
        "mode", "int q p95 (pw)", "int ttft p99", "sim tok/s", "shed", "bursts", "tokens"
    );
    let pred_spec = MultiTenantSpec {
        num_requests: 120,
        tenants: 4,
        ..MultiTenantSpec::default()
    };
    let pred_rows = run_predictive_control(&pred_spec)?;
    for r in &pred_rows {
        println!(
            "{:<13} {:>14.4}s {:>13.4}s {:>10.1}/s {:>6} {:>8} {:>8}",
            r.req_str("mode")?,
            r.req_f64("interactive_queue_wall_p95_postwarm_s")?,
            r.req_f64("interactive_ttft_wall_p99_postwarm_s")?,
            r.req_f64("cluster_throughput_sim")?,
            r.req_usize("shed_requests")?,
            r.get("bursts_detected")
                .and_then(Value::as_usize)
                .unwrap_or(0),
            r.req_usize("tokens")?,
        );
    }
    if let [on, off] = &pred_rows[..] {
        println!(
            "post-warm-up interactive queue-wait p95 reduction with forecasting: {:.1}% \
             (len p90 coverage {:.3}, {} bursts scored)\n",
            reduction_pct(
                off.req_f64("interactive_queue_wall_p95_postwarm_s")?,
                on.req_f64("interactive_queue_wall_p95_postwarm_s")?
            ),
            on.get("len_p90_coverage_pooled")
                .and_then(Value::as_f64)
                .unwrap_or(f64::NAN),
            on.req_usize("bursts_resolved")?,
        );
    }
    write_bench_serve(
        "predictive_control",
        &pred_rows,
        &format!(
            "requests={},tenants={},zipf_s={},seed={:#x},replicas=2,phase=12,calm_steps=6,\
             burst=2/step,warmup=4",
            pred_spec.num_requests, pred_spec.tenants, pred_spec.zipf_s, pred_spec.seed
        ),
    )?;

    // --- chunked prefill: Eq. 12 throughput, mock + Z100 model
    println!("chunked prefill — generation throughput (sim), 4 streams + 3 long prompts");
    println!(
        "{:<10} {:>14} {:>14} {:>8} {:>10} {:>12}",
        "mode", "sim tok/s", "total lat(s)", "chunks", "tokens", "stall(s)"
    );
    let (chunk_tokens, long_prompts, streams, chunk_max_new) = (16, 3, 4, 24);
    let rows = run_chunk_compare(chunk_tokens, long_prompts, streams, chunk_max_new)?;
    let mut chunk_report = Vec::new();
    for r in &rows {
        println!(
            "{:<10} {:>12.1}/s {:>14.4} {:>8} {:>10} {:>12.4}",
            r.mode, r.throughput_sim, r.latency_sim_s, r.prefill_chunks, r.tokens,
            r.chunk_stall_sim_s
        );
        chunk_report.push(r.to_json());
    }
    if let [one, chk] = &rows[..] {
        println!(
            "throughput delta with chunking: {:+.1}%\n",
            gain_pct(one.throughput_sim, chk.throughput_sim)
        );
    }
    let path = write_bench_serve(
        "chunked_prefill_throughput",
        &chunk_report,
        &format!(
            "chunk={chunk_tokens},long={long_prompts},streams={streams},max_new={chunk_max_new}"
        ),
    )?;
    println!("serve summary -> {}", path.display());
    std::fs::create_dir_all("target/bench-reports")?;
    let mut chunk_top = Object::new();
    chunk_top.insert("figure", "chunked-prefill-throughput");
    chunk_top.insert("rows", Value::Array(chunk_report));
    std::fs::write(
        "target/bench-reports/chunked_prefill_throughput.json",
        Value::Object(chunk_top).to_string_pretty(),
    )?;

    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP fig7: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let spec = TraceSpec {
        num_requests: if quick { 8 } else { 24 },
        max_new: if quick { 8 } else { 32 },
        seed: 0xF17_7,
        ..Default::default()
    };

    let mut suite = BenchSuite::quick("fig7-throughput");
    println!("Fig. 7 — generation throughput (Eq. 12), ShareGPT-sim x{} requests", spec.num_requests);
    println!(
        "{:<20} {:>10} {:>14} {:>14} {:>10} {:>8}",
        "model", "config", "sim tok/s", "wall tok/s", "Δsim%", "preempt"
    );
    let mut report = Vec::new();
    for model in rt.manifest.model_names() {
        let mut base_sim = 0.0;
        let mut base_wall = 0.0;
        for cfg in ALL_CONFIGS {
            let row = run_trace(&rt, &model, cfg, &spec, true)?;
            if cfg.name == "original" {
                base_sim = row.throughput_sim;
                base_wall = row.throughput_wall;
            }
            let gain = gain_pct(base_sim, row.throughput_sim);
            println!(
                "{:<20} {:>10} {:>12.1}/s {:>12.1}/s {:>9.2}% {:>8}",
                model, cfg.name, row.throughput_sim, row.throughput_wall, gain, row.preemptions
            );
            let mut o = row.to_json();
            if let Value::Object(obj) = &mut o {
                obj.insert("throughput_gain_sim_pct", gain);
                obj.insert(
                    "throughput_gain_wall_pct",
                    gain_pct(base_wall, row.throughput_wall),
                );
            }
            report.push(o);
            suite.record(
                format!("fig7/{model}/{}", cfg.name),
                &[1.0 / row.throughput_sim.max(1e-9)],
                1.0,
            );
        }
        println!();
    }
    let mut top = Object::new();
    top.insert("figure", "fig7");
    top.insert("rows", Value::Array(report));
    std::fs::create_dir_all("target/bench-reports")?;
    std::fs::write(
        "target/bench-reports/fig7.json",
        Value::Object(top).to_string_pretty(),
    )?;
    suite.report();
    suite.write_json()?;
    Ok(())
}
