//! L3 hot-path micro-benchmarks (`perf-l3` experiment id): block
//! allocator, slot-mapping construction, scheduler rounds, sampling, JSON,
//! FP8 codec.  These are the §Perf targets for the coordinator — the
//! paper's contribution is the cache/kernel path, so L3 must stay cheap.
//! Runs without artifacts.

use llm_coopt::config::{CacheGeometry, EngineConfig, COOPT, ORIGINAL};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::kvcache::{BlockAllocator, CacheManager};
use llm_coopt::runtime::mock::MockBackend;
use llm_coopt::sampling::{sample, SamplingParams};
use llm_coopt::scheduler::Scheduler;
use llm_coopt::util::bench::{black_box, BenchSuite};
use llm_coopt::util::fp8;
use llm_coopt::util::json;
use llm_coopt::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("l3-micro");
    suite.measure = std::time::Duration::from_millis(800);

    // allocator alloc/free cycle
    let mut alloc = BlockAllocator::new(4096);
    suite.bench("allocator/alloc_free_64", || {
        let ids: Vec<_> = (0..64).map(|_| alloc.alloc().unwrap()).collect();
        for id in ids {
            alloc.decref(id);
        }
    });

    // prefill slot-mapping build (Opt-KV SkipSet path vs baseline padding)
    let geometry = CacheGeometry::default();
    let prompt: Vec<u32> = (0..100).map(|i| (i * 7 % 251) as u32).collect();
    for (name, cfg) in [("coopt", COOPT), ("original", ORIGINAL)] {
        let mut cm = CacheManager::new(geometry);
        let mut id = 0u64;
        suite.bench(format!("cache/prefill_plan/{name}"), || {
            id += 1;
            let plan = cm.prefill(id, black_box(&prompt), &cfg).unwrap();
            black_box(&plan);
            cm.free_seq(id);
        });
    }

    // decode append (slot reservation) steady state
    {
        let mut cm = CacheManager::new(CacheGeometry {
            num_pool_blocks: 4096,
            max_blocks: 4096 / 16,
            ..geometry
        });
        cm.prefill(1, &prompt, &COOPT).unwrap();
        let mut n = 0u64;
        suite.bench("cache/append_token", || {
            n += 1;
            if cm.seq_len(1) + 2 >= 4096 {
                cm.free_seq(1);
                cm.prefill(1, &prompt, &COOPT).unwrap();
            }
            black_box(cm.append_token(1).unwrap());
        });
    }

    // scheduler round at batch 8 with queue pressure
    {
        let mut sched = Scheduler::new(8);
        let cm = CacheManager::new(geometry);
        for i in 0..64u64 {
            sched.submit(i, 40);
        }
        suite.bench("scheduler/schedule_round", || {
            black_box(sched.schedule(&cm, &COOPT));
        });
    }

    // sampling
    let mut rng = Rng::new(0);
    let logits: Vec<f32> = (0..260).map(|i| ((i * 37 % 101) as f32) * 0.05).collect();
    suite.bench("sampling/greedy", || {
        black_box(sample(
            black_box(&logits),
            &SamplingParams::default(),
            &mut rng,
        ));
    });
    suite.bench("sampling/topk_topp", || {
        black_box(sample(
            black_box(&logits),
            &SamplingParams {
                temperature: 0.8,
                top_k: 40,
                top_p: 0.9,
            },
            &mut rng,
        ));
    });

    // fp8 codec (rust mirror)
    let xs: Vec<f32> = (0..1024).map(|i| ((i as f32) - 512.0) * 0.37).collect();
    suite.bench_units("fp8/quantize_1k", 1024.0, &mut || {
        black_box(fp8::quantize(black_box(&xs)));
    });

    // json parse/serialize (server request path)
    let body = r#"{"prompt": "Q: 2+3=? A) 5 B) 6 C) 4 D) 9\nAnswer:", "max_new_tokens": 16, "temperature": 0.7}"#;
    suite.bench("json/parse_request", || {
        black_box(json::parse(black_box(body)).unwrap());
    });

    // full engine round over the mock backend = pure-L3 cost of a step
    {
        let be = MockBackend::new();
        let mut e =
            Engine::new(be, EngineConfig::new("llama-7b-sim", COOPT)).without_cost_model();
        suite.bench("engine/round_mock_batch8", || {
            for i in 0..8 {
                e.submit(GenRequest::greedy(format!("bench prompt {i}"), 4))
                    .unwrap();
            }
            black_box(e.run_to_completion().unwrap());
        });
    }

    // --- real PJRT step costs (per opt config), when artifacts exist.
    // This is the §Perf measurement separating kernel-execution time from
    // the cache round-trip the CPU-PJRT tuple path forces (DESIGN.md §5).
    let dir = llm_coopt::config::artifacts_dir();
    if llm_coopt::runtime::artifacts_available(&dir) {
        let rt = llm_coopt::runtime::Runtime::new(&dir).expect("runtime");
        for cfg in [ORIGINAL, COOPT] {
            use llm_coopt::runtime::Backend;
            let mut m = rt.load_model("llama-7b-sim", cfg).unwrap();
            let g = *m.geometry();
            let mut toks = vec![256i32; g.max_seq];
            toks[0] = 81;
            toks[1] = 58;
            let mut slots = vec![-1i32; g.max_seq];
            slots[0] = 0;
            slots[1] = 1;
            m.prefill(&toks, 2, &slots).unwrap();
            let mut token_ids = vec![256i32; g.max_batch];
            token_ids[0] = 65;
            let mut positions = vec![0i32; g.max_batch];
            let mut ctx = vec![0i32; g.max_batch];
            let mut sm = vec![-1i32; g.max_batch];
            let mut bt = vec![0i32; g.max_batch * g.max_blocks];
            for (i, b) in bt.iter_mut().enumerate().take(g.max_blocks) {
                *b = i as i32;
            }
            let mut pos = 2i32;
            suite.bench(format!("pjrt/decode_step/{}", cfg.name), || {
                if pos as usize + 2 >= g.max_context() {
                    pos = 2;
                }
                positions[0] = pos;
                ctx[0] = pos + 1;
                sm[0] = pos;
                black_box(m.decode(&token_ids, &positions, &bt, &ctx, &sm).unwrap());
                pos += 1;
            });
            let mut pc = 0u32;
            suite.bench(format!("pjrt/prefill/{}", cfg.name), || {
                pc += 1;
                toks[1] = (pc % 200) as i32;
                black_box(m.prefill(&toks, 2, &slots).unwrap());
            });
        }
    } else {
        eprintln!("(artifacts missing: skipping pjrt step benches)");
    }

    suite.report();
    suite.write_json().ok();
}
