//! Ablation benches (`ablation-block-size`, `ablation-seqlen` experiment
//! ids): block-size sweep and the long-context crossover that motivates
//! Opt-Pa (§3.3).  Analytical Z100 model; runs without artifacts.

use llm_coopt::config::{builtin_preset, ALL_CONFIGS, COOPT, OPTPA, ORIGINAL};
use llm_coopt::platform::{CostModel, SeqCostInput};
use llm_coopt::util::json::{Object, Value};

fn main() -> anyhow::Result<()> {
    let preset = builtin_preset("llama-13b-sim")?;
    let mut rows = Vec::new();

    // --- block-size sweep (coopt): paging granularity vs step time
    println!("ablation: block size sweep (llama-13b twin, ctx 512, batch 8)");
    println!("{:>6} {:>12} {:>12} {:>12}", "B", "orig(ms)", "coopt(ms)", "gain%");
    for bs in [8usize, 16, 32, 64] {
        let cm = CostModel::for_preset(&preset, bs);
        let seqs: Vec<SeqCostInput> = (0..8)
            .map(|_| SeqCostInput {
                ctx_len: 512,
                allocated_blocks: 1024 / bs,
            })
            .collect();
        let o = cm.decode_step(&seqs, &ORIGINAL, 1, 8).total_s;
        let c = cm.decode_step(&seqs, &COOPT, 1, 8).total_s;
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>11.2}%",
            bs,
            o * 1e3,
            c * 1e3,
            (o / c - 1.0) * 100.0
        );
        let mut r = Object::new();
        r.insert("sweep", "block_size");
        r.insert("block_size", bs);
        r.insert("orig_s", o);
        r.insert("coopt_s", c);
        rows.push(Value::Object(r));
    }

    // --- long-sequence sweep: Opt-Pa gain vs context (padding fixed at 4096)
    println!("\nablation: Opt-Pa gain vs context length (allocation padded to 4096 tokens)");
    println!("{:>8} {:>12} {:>12} {:>10}", "ctx", "orig(ms)", "optpa(ms)", "gain%");
    let cm = CostModel::for_preset(&preset, 16);
    for ctx in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let seqs: Vec<SeqCostInput> = (0..8)
            .map(|_| SeqCostInput {
                ctx_len: ctx,
                allocated_blocks: 4096 / 16,
            })
            .collect();
        let o = cm.decode_step(&seqs, &ORIGINAL, 1, 8).total_s;
        let p = cm.decode_step(&seqs, &OPTPA, 1, 8).total_s;
        println!(
            "{:>8} {:>12.3} {:>12.3} {:>9.2}%",
            ctx,
            o * 1e3,
            p * 1e3,
            (o / p - 1.0) * 100.0
        );
        let mut r = Object::new();
        r.insert("sweep", "seqlen");
        r.insert("ctx", ctx);
        r.insert("orig_s", o);
        r.insert("optpa_s", p);
        rows.push(Value::Object(r));
    }

    // --- capacity coupling per model (the Fig. 7 mechanism)
    println!("\npaper-scale KV pool blocks per config:");
    for name in [
        "llama-7b-sim",
        "llama2-7b-sim",
        "llama-13b-sim",
        "llama2-13b-sim",
        "llama-pro-8b-sim",
    ] {
        let p = builtin_preset(name)?;
        let cm = CostModel::for_preset(&p, 16);
        print!("  {:<18}", name);
        for cfg in ALL_CONFIGS {
            print!(" {}={}", cfg.name, cm.paper_pool_blocks(&cfg));
        }
        println!();
    }

    let mut top = Object::new();
    top.insert("figure", "ablation");
    top.insert("rows", Value::Array(rows));
    std::fs::create_dir_all("target/bench-reports")?;
    std::fs::write(
        "target/bench-reports/ablation.json",
        Value::Object(top).to_string_pretty(),
    )?;
    Ok(())
}
