//! **Fig. 6 reproduction**: inference latency per model, Original vs each
//! optimization vs LLM-CoOpt, on the ShareGPT-sim trace (Eq. 11 total
//! latency over the simulated-Z100 clock; wallclock reported alongside).
//!
//! Paper's reported CoOpt latency reductions:
//!   LLaMa-7B 5.59% | LLaMa2-7B 5.48% | LLaMa-13B 6.18% |
//!   LLaMa2-13B 6.75% | LLaMa-Pro-8B 4.82%
//! We reproduce the *shape* (CoOpt always wins, cuts cluster mid-single-
//! digit %, 13B-class >= 7B-class); absolutes depend on the Z100 model.
//!
//! Also reports the **chunked prefill** (Opt-Pa step 1) latency deltas on
//! the deterministic mock + Z100 model (runs without artifacts): p50/p95
//! decode inter-token latency with chunking on vs off, chunk counts, and
//! inter-chunk stall — the paper's long-prompt mixed-batch scenario.
//!
//! Run: cargo bench --bench bench_latency

use llm_coopt::config::{artifacts_dir, ALL_CONFIGS};
use llm_coopt::runtime::{artifacts_available, Runtime};
use llm_coopt::util::bench::BenchSuite;
use llm_coopt::util::json::{Object, Value};
use llm_coopt::workload::harness::{
    reduction_pct, run_chunk_compare, run_trace, write_bench_serve,
};
use llm_coopt::workload::TraceSpec;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("COOPT_BENCH_QUICK").is_ok();

    // (the swap-vs-recompute tiered-KV comparison — including its ITL
    // percentiles — is owned by bench_throughput, which writes the
    // swap_vs_recompute section of BENCH_serve.json; running the same
    // simulation here would just duplicate the rows)

    // --- chunked prefill: decode inter-token latency, mock + Z100 model
    println!("chunked prefill — p95 decode inter-token latency (sim), 4 streams + 3 long prompts");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>8} {:>12}",
        "mode", "p50 itl(s)", "p95 itl(s)", "max itl(s)", "chunks", "stall(s)"
    );
    let (chunk_tokens, long_prompts, streams, chunk_max_new) = (16, 3, 4, 24);
    let rows = run_chunk_compare(chunk_tokens, long_prompts, streams, chunk_max_new)?;
    let mut chunk_report = Vec::new();
    for r in &rows {
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>12.4} {:>8} {:>12.4}",
            r.mode, r.itl_sim_p50_s, r.itl_sim_p95_s, r.itl_sim_max_s, r.prefill_chunks,
            r.chunk_stall_sim_s
        );
        chunk_report.push(r.to_json());
    }
    if let [one, chk] = &rows[..] {
        println!(
            "p95 itl reduction with chunking: {:.1}%\n",
            reduction_pct(one.itl_sim_p95_s, chk.itl_sim_p95_s)
        );
    }
    let path = write_bench_serve(
        "chunked_prefill_latency",
        &chunk_report,
        &format!(
            "chunk={chunk_tokens},long={long_prompts},streams={streams},max_new={chunk_max_new}"
        ),
    )?;
    println!("serve summary -> {}", path.display());
    std::fs::create_dir_all("target/bench-reports")?;
    let mut chunk_top = Object::new();
    chunk_top.insert("figure", "chunked-prefill-latency");
    chunk_top.insert("rows", Value::Array(chunk_report));
    std::fs::write(
        "target/bench-reports/chunked_prefill_latency.json",
        Value::Object(chunk_top).to_string_pretty(),
    )?;

    let dir = artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP fig6: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let spec = TraceSpec {
        num_requests: if quick { 8 } else { 24 },
        max_new: if quick { 8 } else { 32 },
        seed: 0xF16_6,
        ..Default::default()
    };

    let mut suite = BenchSuite::quick("fig6-latency");
    println!("Fig. 6 — total inference latency (Eq. 11), ShareGPT-sim x{} requests", spec.num_requests);
    println!(
        "{:<20} {:>10} {:>12} {:>12} {:>10} {:>12}",
        "model", "config", "sim lat(s)", "wall lat(s)", "Δsim%", "pool blocks"
    );
    let mut report = Vec::new();
    for model in rt.manifest.model_names() {
        let mut base_sim = 0.0;
        let mut base_wall = 0.0;
        for cfg in ALL_CONFIGS {
            let row = run_trace(&rt, &model, cfg, &spec, true)?;
            if cfg.name == "original" {
                base_sim = row.latency_sim_s;
                base_wall = row.latency_wall_s;
            }
            let red = reduction_pct(base_sim, row.latency_sim_s);
            println!(
                "{:<20} {:>10} {:>12.4} {:>12.3} {:>9.2}% {:>12}",
                model, cfg.name, row.latency_sim_s, row.latency_wall_s, red, row.pool_blocks
            );
            let mut o = row.to_json();
            if let Value::Object(obj) = &mut o {
                obj.insert("latency_reduction_sim_pct", red);
                obj.insert(
                    "latency_reduction_wall_pct",
                    reduction_pct(base_wall, row.latency_wall_s),
                );
            }
            report.push(o);
            suite.record(
                format!("fig6/{model}/{}", cfg.name),
                &[row.latency_sim_s],
                row.tokens as f64,
            );
        }
        println!();
    }
    let mut top = Object::new();
    top.insert("figure", "fig6");
    top.insert("rows", Value::Array(report));
    std::fs::create_dir_all("target/bench-reports")?;
    std::fs::write(
        "target/bench-reports/fig6.json",
        Value::Object(top).to_string_pretty(),
    )?;
    suite.report();
    suite.write_json()?;
    Ok(())
}
