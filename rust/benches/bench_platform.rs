//! Z100 platform-model benches: Eq. 3 effective-latency sweep, roofline
//! checks, and the per-config step-time decomposition used by DESIGN.md
//! (`eq3-hierarchy` experiment id).  Pure analytical; runs without
//! artifacts.

use llm_coopt::config::{builtin_presets, ALL_CONFIGS};
use llm_coopt::platform::{CostModel, SeqCostInput};
use llm_coopt::util::bench::{black_box, BenchSuite};

fn main() {
    let mut suite = BenchSuite::quick("platform-model");

    // Eq. 3 sweep: effective latency monotone in hit rate
    let cm = CostModel::for_preset(&builtin_presets()[2], 16);
    println!("Eq. 3 sweep (hit rate -> effective latency cycles):");
    let mut prev = f64::INFINITY;
    for i in 0..=10 {
        let h = i as f64 / 10.0;
        let t = cm.effective_latency_cycles(h);
        println!("  H={h:.1}  T_eff={t:.0} cycles");
        assert!(t <= prev);
        prev = t;
    }

    // step-cost evaluation speed (the engine calls this every step — it
    // must be non-perturbing in the serving hot loop)
    let seqs: Vec<SeqCostInput> = (0..8)
        .map(|i| SeqCostInput {
            ctx_len: 64 + i * 13,
            allocated_blocks: 64,
        })
        .collect();
    for cfg in ALL_CONFIGS {
        suite.bench(format!("decode_step_cost/{}", cfg.name), || {
            black_box(cm.decode_step(black_box(&seqs), &cfg, 1, 8));
        });
    }
    suite.bench("prefill_cost", || {
        black_box(cm.prefill(black_box(200), &ALL_CONFIGS[4]));
    });

    // decomposition table per model at ctx=512
    println!("\nper-config decode step decomposition (ctx 512, batch 8):");
    for preset in builtin_presets() {
        let cm = CostModel::for_preset(&preset, 16);
        let seqs: Vec<SeqCostInput> = (0..8)
            .map(|_| SeqCostInput {
                ctx_len: 512,
                allocated_blocks: 64,
            })
            .collect();
        print!("  {:<18}", preset.name);
        for cfg in ALL_CONFIGS {
            let c = cm.decode_step(&seqs, &cfg, 1, 8);
            print!(" {}={:.2}ms", cfg.name, c.total_s * 1e3);
        }
        println!();
    }

    suite.report();
    suite.write_json().ok();
}
