//! Stub of the `xla` PJRT bindings (DESIGN.md "offline-toolchain
//! substitutions").
//!
//! The real crate wraps the XLA C API; this environment has no XLA
//! toolchain, so the runtime half of the stack is compiled against this
//! stub and every entry point reports PJRT as unavailable.  The L3 stack
//! is backend-generic (see `runtime::Backend`), every artifact-dependent
//! test and bench SKIPs when `artifacts/manifest.json` is absent, and the
//! mock backend carries the engine test suite — so tier-1 stays green
//! without XLA while the API shape matches the real bindings for builds
//! that swap the genuine crate back in.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (built against the vendored xla stub; \
         install the XLA toolchain and swap in the real `xla` crate to run artifacts)"
    )))
}

/// Element types accepted by host<->device transfers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for u8 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u32 {}

#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtDevice;

#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(Literal.to_vec::<f32>().is_err());
    }
}
