//! Offline stand-in for the `anyhow` crate (DESIGN.md "offline-toolchain
//! substitutions"): the build environment has no crates.io access, so the
//! subset of the anyhow API this workspace uses is implemented here —
//! `Error`, `Result`, the `anyhow!`/`bail!`/`ensure!` macros, and the
//! `Context` extension trait for `Result` and `Option`.
//!
//! Error values carry a single pre-rendered message; `context` prepends,
//! so `{e}` and `{e:#}` both print the full chain outermost-first, which
//! is what the callers format.

use std::fmt;

/// `anyhow::Result<T>` with the same defaulted error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A rendered error with its context chain folded into one message.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (outermost-first, anyhow's `{:#}` shape).
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("got {n} items");
        assert_eq!(e.to_string(), "got 3 items");
        let e = anyhow!("got {} items", 4);
        assert_eq!(e.to_string(), "got 4 items");
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope: {}", 7);
            }
            let _f: Result<()> = Err(io_err()).map_err(Into::into);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "nope: 7");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "slot 2");
    }

    #[test]
    fn from_std_error_keeps_sources() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("missing thing"));
        // alternate formatting is the same rendered chain
        assert_eq!(format!("{e:#}"), e.to_string());
    }

    #[test]
    fn ensure_forms() {
        fn check(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            Ok(())
        }
        assert!(check(3).is_ok());
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
    }
}
