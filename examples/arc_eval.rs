//! Reproduce **Table 1 / Table 2** (paper §4.3.2): ARC-sim accuracy before
//! (Original) vs after (LLM-CoOpt) optimization, for every model.
//!
//! ```bash
//! cargo run --release --example arc_eval -- --set challenge   # Table 1
//! cargo run --release --example arc_eval -- --set easy        # Table 2
//! ```

use llm_coopt::config::{artifacts_dir, opt_config, EngineConfig};
use llm_coopt::coordinator::Engine;
use llm_coopt::eval::{agreement, evaluate};
use llm_coopt::runtime::Runtime;
use llm_coopt::util::cli::Cli;
use llm_coopt::workload::load_mcq_set;

fn main() -> anyhow::Result<()> {
    llm_coopt::util::logging::init();
    let mut cli = Cli::new("arc_eval", "Reproduce Tables 1-2 (accuracy)");
    cli.flag("set", "easy", "eval split: easy (Table 2) | challenge (Table 1)")
        .flag("models", "all", "comma-separated models or 'all'")
        .flag("configs", "original,coopt", "configs to compare")
        .flag("limit", "0", "0 = full set, N = first N questions");
    let args = cli.parse_or_exit();

    let dir = artifacts_dir();
    let rt = Runtime::new(&dir)?;
    let split = args.get("set");
    let file = rt
        .manifest
        .eval_sets
        .iter()
        .find(|(s, _)| s == split)
        .map(|(_, f)| f.clone())
        .ok_or_else(|| anyhow::anyhow!("split '{split}' not in manifest"))?;
    let mut set = load_mcq_set(dir.join(file))?;
    let limit = args.get_usize("limit");
    if limit > 0 {
        set.questions.truncate(limit);
    }

    let models = if args.get("models") == "all" {
        rt.manifest.model_names()
    } else {
        args.get_list("models")
    };
    let configs = args.get_list("configs");

    let table = if split == "challenge" { "Table 1 (ARC-C-sim)" } else { "Table 2 (ARC-E-sim)" };
    println!("{table}: accuracy over {} questions\n", set.questions.len());
    print!("{:<20}", "Model");
    for c in &configs {
        print!(" {:>12}", c);
    }
    println!(" {:>12}", "agreement");

    for model in &models {
        print!("{:<20}", model);
        let mut first: Option<llm_coopt::eval::EvalResult> = None;
        let mut last_agreement = 1.0;
        for cfg_name in &configs {
            let opt = opt_config(cfg_name)?;
            let mrt = rt.load_model(model, opt)?;
            let mut engine = Engine::new(mrt, EngineConfig::new(model, opt));
            let r = evaluate(&mut engine, &set)?;
            print!(" {:>11.2}%", r.accuracy_pct());
            if let Some(f) = &first {
                last_agreement = agreement(f, &r);
            } else {
                first = Some(r);
            }
        }
        println!(" {:>11.1}%", last_agreement * 100.0);
    }
    println!(
        "\n(agreement = fraction of questions where both configs chose the same letter;\n\
         the paper's claim is accuracy preservation under FP8-KV + GQA + Opt-Pa)"
    );
    Ok(())
}
