//! Quickstart: load a model with the LLM-CoOpt config and generate text.
//!
//! ```bash
//! make artifacts           # once
//! cargo run --release --example quickstart
//! ```

use llm_coopt::config::{artifacts_dir, opt_config, EngineConfig};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    llm_coopt::util::logging::init();
    let model = "llama-13b-sim";
    let opt = opt_config("coopt")?;

    // 1. open the artifacts (HLO graphs + weights lowered by `make artifacts`)
    let rt = Runtime::new(artifacts_dir())?;

    // 2. compile + upload the model once; the KV pool lives on-device
    let mrt = rt.load_model(model, opt)?;
    println!("loaded {model}/{} (compile {:?})", opt.name, mrt.compile_time);

    // 3. serve a small batch through the continuous-batching engine
    let mut engine = Engine::new(mrt, EngineConfig::new(model, opt));
    let prompts = [
        "Q: 3+4=? A) 7 B) 8 C) 6 D) 5\nAnswer:",
        "Q: 2+9=? A) 10 B) 12 C) 11 D) 13\nAnswer:",
        "Q: 5+5=? A) 9 B) 10 C) 11 D) 12\nAnswer:",
    ];
    let reqs = prompts
        .iter()
        .map(|p| GenRequest::greedy(*p, 8))
        .collect();
    let results = engine.generate(reqs)?;

    for r in &results {
        println!("\nprompt    : {}", r.prompt.trim_end());
        println!("completion: {:?}", r.text);
        println!(
            "  tokens={} finish={:?} wall={:.1}ms sim(Z100)={:.3}ms",
            r.generated_tokens,
            r.finish,
            r.latency_s * 1e3,
            r.sim_time_s * 1e3
        );
    }

    println!(
        "\nengine: {} decode steps, throughput {:.1} tok/s (wall), {:.1} tok/s (simulated Z100)",
        engine.metrics.decode_steps,
        engine.metrics.throughput_wall(),
        engine.metrics.throughput_sim()
    );
    let st = engine.cache_stats();
    println!(
        "cache: {} prefix hits, {} skipped writes (SkipSet), fragmentation {:.1}%",
        st.prefix_hits,
        st.skipped_writes,
        st.fragmentation * 100.0
    );
    Ok(())
}
