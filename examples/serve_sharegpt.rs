//! END-TO-END VALIDATION DRIVER (DESIGN.md): serve a ShareGPT-like batched
//! workload through the full three-layer stack for every (model, config)
//! pair requested, and report latency (Eq. 11) + throughput (Eq. 12) on
//! both the wallclock and the simulated-Z100 clock.
//!
//! This is the run recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example serve_sharegpt -- \
//!     --models llama-13b-sim --configs original,coopt --requests 40
//! ```

use llm_coopt::config::{artifacts_dir, opt_config, EngineConfig};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::platform::CostModel;
use llm_coopt::runtime::Runtime;
use llm_coopt::util::cli::Cli;
use llm_coopt::workload::{sharegpt_trace, TraceSpec};

fn main() -> anyhow::Result<()> {
    llm_coopt::util::logging::init();
    let mut cli = Cli::new("serve_sharegpt", "E2E serving driver (ShareGPT-sim)");
    cli.flag("models", "llama-13b-sim", "comma-separated model list")
        .flag("configs", "original,coopt", "comma-separated config list")
        .flag("requests", "40", "number of requests")
        .flag("seed", "53518", "trace seed")
        .bool_flag("capacity", "derive pool size from the Z100 memory model");
    let args = cli.parse_or_exit();

    let rt = Runtime::new(artifacts_dir())?;
    let spec = TraceSpec {
        num_requests: args.get_usize("requests"),
        seed: args.get_usize("seed") as u64,
        ..Default::default()
    };
    let trace = sharegpt_trace(&spec);
    println!(
        "trace: {} requests, avg prompt {:.0} chars, avg max_new {:.1}",
        trace.len(),
        trace.iter().map(|r| r.prompt.len()).sum::<usize>() as f64 / trace.len() as f64,
        trace.iter().map(|r| r.max_new_tokens).sum::<usize>() as f64 / trace.len() as f64
    );
    println!(
        "\n{:<18} {:>9} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "model/config", "tokens", "wall tput", "sim tput", "sum lat(s)", "sim lat(s)", "p99 lat", "L3 ovh"
    );

    for model in args.get_list("models") {
        for cfg_name in args.get_list("configs") {
            let opt = opt_config(&cfg_name)?;
            let mut mrt = rt.load_model(&model, opt)?;
            use llm_coopt::runtime::Backend;
            let mut geometry = *mrt.geometry();
            if args.get_bool("capacity") {
                // memory-capacity coupling (DESIGN.md): pool size follows
                // the paper-scale free memory under this config
                let cm = CostModel::for_preset(mrt.preset(), geometry.block_size);
                geometry.num_pool_blocks =
                    cm.sim_pool_blocks(&opt, 12.0, 16, geometry.num_pool_blocks);
            }
            mrt.reset_cache()?;
            let mut engine = Engine::new(mrt, EngineConfig::new(&model, opt));
            for req in &trace {
                engine.submit(GenRequest {
                    prompt: req.prompt.clone(),
                    max_new_tokens: req.max_new_tokens,
                    sampling: req.sampling,
                    ignore_eos: true,
                })?;
            }
            let _results = engine.run_to_completion()?;
            let m = &mut engine.metrics;
            println!(
                "{:<18} {:>9} {:>10.1}/s {:>10.1}/s {:>12.3} {:>12.4} {:>11.3}s {:>7.1}%",
                format!("{model}/{cfg_name}"),
                m.tokens_generated,
                m.throughput_wall(),
                m.throughput_sim(),
                m.total_latency_wall_s(),
                m.total_latency_sim_s(),
                m.latency_wall.p99(),
                m.coordinator_overhead_frac() * 100.0
            );
        }
    }
    Ok(())
}
