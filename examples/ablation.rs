//! Ablation: contribution of each optimization (Opt-KV, Opt-GQA, Opt-Pa)
//! to the simulated-Z100 step time, swept over context length — the
//! decomposition behind Fig. 6/7 plus the long-sequence motivation of
//! §3.3 (Opt-Pa's win grows with padding waste).
//!
//! Pure platform-model sweep (no PJRT needed): runs anywhere.

use llm_coopt::config::{builtin_presets, ALL_CONFIGS, ORIGINAL};
use llm_coopt::platform::{CostModel, SeqCostInput};
use llm_coopt::util::cli::Cli;

fn main() {
    let mut cli = Cli::new("ablation", "per-optimization step-time decomposition");
    cli.flag("batch", "8", "decode batch size")
        .flag("block-size", "16", "paged block size");
    let args = cli.parse_or_exit();
    let batch = args.get_usize("batch");
    let bs = args.get_usize("block-size");

    for preset in builtin_presets() {
        let cm = CostModel::for_preset(&preset, bs);
        println!(
            "\n=== {} (paper twin: {} layers, d={} / Z100 cost model) ===",
            preset.name, preset.paper_layers, preset.paper_d_model
        );
        println!(
            "{:<10} {:>8} {:>12} {:>12} {:>12} {:>12} {:>9}",
            "config", "ctx", "weights", "kv mem", "compute", "overhead", "Δ vs orig"
        );
        for ctx in [128usize, 512, 1024, 2048] {
            // baseline over-allocates: padded prefill to the next 512
            let padded_blocks = ctx.next_multiple_of(512) / bs;
            let seqs: Vec<SeqCostInput> = (0..batch)
                .map(|_| SeqCostInput {
                    ctx_len: ctx,
                    allocated_blocks: padded_blocks,
                })
                .collect();
            let orig = cm.decode_step(&seqs, &ORIGINAL, 1, batch);
            for opt in ALL_CONFIGS {
                let c = cm.decode_step(&seqs, &opt, 1, batch);
                println!(
                    "{:<10} {:>8} {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>10.3}ms {:>8.2}%",
                    opt.name,
                    ctx,
                    c.weights_mem_s * 1e3,
                    c.kv_mem_s * 1e3,
                    c.compute_s * 1e3,
                    c.overhead_s * 1e3,
                    (orig.total_s / c.total_s - 1.0) * 100.0
                );
            }
            println!();
        }
        // capacity coupling: pool blocks per config at paper scale
        print!("paper-scale KV pool blocks: ");
        for opt in ALL_CONFIGS {
            print!("{}={} ", opt.name, cm.paper_pool_blocks(&opt));
        }
        println!();
    }
}
