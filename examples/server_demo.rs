//! HTTP serving demo: start the server on a real model, fire a few client
//! requests (concurrently, so they batch), print the responses + metrics,
//! then shut down.
//!
//! ```bash
//! cargo run --release --example server_demo -- --model llama-7b-sim --config coopt
//! ```

use std::sync::atomic::Ordering;

use llm_coopt::config::{artifacts_dir, opt_config, EngineConfig};
use llm_coopt::coordinator::Engine;
use llm_coopt::runtime::Runtime;
use llm_coopt::server::{Client, EngineHandle, Server};
use llm_coopt::util::cli::Cli;
use llm_coopt::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    llm_coopt::util::logging::init();
    let mut cli = Cli::new("server_demo", "HTTP serving demo");
    cli.flag("model", "llama-7b-sim", "model preset")
        .flag("config", "coopt", "opt config")
        .flag("clients", "4", "concurrent clients");
    let args = cli.parse_or_exit();

    let model = args.get("model").to_string();
    let opt = opt_config(args.get("config"))?;
    let rt = Runtime::new(artifacts_dir())?;
    let mrt = rt.load_model(&model, opt)?;
    let engine = Engine::new(mrt, EngineConfig::new(&model, opt));

    let server = Server::bind("127.0.0.1:0", EngineHandle::spawn(engine), 8)?;
    let addr = server.addr.to_string();
    let stop = server.stop_flag();
    let srv = std::thread::spawn(move || server.serve());
    println!("server up at http://{addr}");

    let client = Client::new(addr.clone());
    let (_, health) = client.get("/health")?;
    println!("health: {health}");

    // concurrent clients -> batched inside the engine
    let n = args.get_usize("clients");
    let pool = ThreadPool::new(n);
    let addr2 = addr.clone();
    let replies = pool.map((0..n as u32).collect::<Vec<_>>(), move |i| {
        let c = Client::new(addr2.clone());
        c.generate(
            &format!("Q: {}+{}=? A) {} B) 9 C) 1 D) 0\nAnswer:", i, i + 1, 2 * i + 1),
            6,
        )
    });
    for (i, r) in replies.into_iter().enumerate() {
        let v = r?;
        println!(
            "client {i}: text={:?} tokens={} ttft={:.1}ms",
            v.req_str("text")?,
            v.req_usize("generated_tokens")?,
            v.req_f64("ttft_s")? * 1e3
        );
    }

    let (_, metrics) = client.get("/metrics")?;
    println!("\n/metrics: {}", metrics.to_string_pretty());

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap()?;
    Ok(())
}
