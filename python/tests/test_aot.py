"""AOT pipeline: lowering works for every (config, phase), the HLO text is
parseable-looking, weights serialization round-trips, and the manifest
matches what the rust runtime expects."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import init_params
from compile.presets import (MODELS, OPT_CONFIGS, graph_weight_names,
                             weight_names, weight_shapes)


@pytest.fixture(scope="module")
def preset():
    return MODELS["llama-7b-sim"]


@pytest.mark.parametrize("cfg", list(OPT_CONFIGS))
@pytest.mark.parametrize("phase", ["prefill", "decode"])
def test_lowering_produces_hlo_text(preset, cfg, phase):
    txt = aot.lower_graph(preset, OPT_CONFIGS[cfg], phase)
    assert txt.startswith("HloModule"), txt[:80]
    assert "ENTRY" in txt
    # ENTRY parameter count = referenced weights + runtime inputs
    # (lower_graph itself asserts this; double-check the manifest contract)
    n_w = len(graph_weight_names(preset, OPT_CONFIGS[cfg].gqa))
    n_rt = len(aot.runtime_inputs(preset, OPT_CONFIGS[cfg], phase))
    assert txt.split("ENTRY", 1)[1].count(" parameter(") == n_w + n_rt


def test_weights_bin_round_trip(preset, tmp_path):
    params = {k: np.asarray(v) for k, v in init_params(preset, seed=3).items()}
    path = tmp_path / "w.bin"
    offsets = aot.write_weights_bin(preset, params, str(path))
    raw = path.read_bytes()
    total = sum(o["nbytes"] for o in offsets.values())
    assert len(raw) == total
    for name in weight_names(preset):
        o = offsets[name]
        arr = np.frombuffer(raw[o["offset"]:o["offset"] + o["nbytes"]],
                            dtype="<f4").reshape(o["shape"])
        np.testing.assert_array_equal(arr, params[name])


def test_runtime_inputs_schema(preset):
    for cfg_name, opt in OPT_CONFIGS.items():
        rt = aot.runtime_inputs(preset, opt, "decode")
        names = [n for n, _, _ in rt]
        base = ["token_ids", "positions", "block_tables", "ctx_lens",
                "slot_mapping", "k_cache", "v_cache"]
        if opt.fp8_kv:
            base += ["k_scale", "v_scale"]
        assert names == base, cfg_name
        # fp8 cache dtype is u8
        cache_dt = dict((n, d) for n, d, _ in rt)["k_cache"]
        assert cache_dt == ("u8" if opt.fp8_kv else "f32")


def test_cache_shapes_respect_gqa(preset):
    kv_gqa = aot.cache_shapes(preset, OPT_CONFIGS["coopt"])[0][2]
    kv_mha = aot.cache_shapes(preset, OPT_CONFIGS["original"])[0][2]
    assert kv_gqa[3] == preset.n_kv_heads_gqa
    assert kv_mha[3] == preset.n_heads
    assert kv_gqa[3] < kv_mha[3]


def test_l1_report_fields(preset):
    for opt in OPT_CONFIGS.values():
        r = aot.l1_report(preset, opt)
        assert r["vmem_bytes_per_program"] > 0
        assert r["vmem_double_buffered"] < 64 * 1024, (
            "per-program VMEM must stay double-bufferable under 64KB")
        assert 0 < r["mxu_tile_utilization"] <= 1


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_manifest_matches_presets():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        m = json.load(f)
    for name, preset in MODELS.items():
        md = m["models"][name]
        assert md["layers"] == preset.layers
        assert md["n_heads"] == preset.n_heads
        shapes = weight_shapes(preset)
        for w in md["weights"]:
            assert tuple(w["shape"]) == tuple(shapes[w["name"]])
    # every config x phase graph present for every model
    combos = {(g["model"], g["config"], g["phase"]) for g in m["graphs"]}
    for name in MODELS:
        for cfg in OPT_CONFIGS:
            for ph in ("prefill", "decode"):
                assert (name, cfg, ph) in combos
