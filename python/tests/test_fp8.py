"""FP8 E4M3FN codec: bit-exactness against ml_dtypes + properties."""

import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8


def test_decode_all_256_codes_bit_exact():
    codes = np.arange(256, dtype=np.uint8)
    ours = np.asarray(fp8.e4m3_decode(codes))
    ref = codes.view(ml_dtypes.float8_e4m3fn).astype(np.float32)
    for c in range(256):
        if np.isnan(ref[c]):
            assert np.isnan(ours[c]), hex(c)
        else:
            assert ours[c] == ref[c], hex(c)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_encode_matches_ml_dtypes_in_range(seed):
    rng = np.random.default_rng(seed)
    xs = np.concatenate([
        rng.normal(0, 100, 500),
        rng.normal(0, 1e-2, 500),
        rng.uniform(-448, 448, 500),
        (rng.integers(0, 2**9, 100) + 0.5) * 2**-9,  # subnormal ties
    ]).astype(np.float32)
    xs = xs[np.abs(xs) <= 448.0]
    enc = np.asarray(fp8.e4m3_encode(xs))
    ref = xs.astype(ml_dtypes.float8_e4m3fn).view(np.uint8)
    np.testing.assert_array_equal(enc, ref)


def test_encode_saturates_beyond_max():
    out = np.asarray(fp8.e4m3_encode(np.array([1e9, -1e9, 449.0, 448.0],
                                              np.float32)))
    assert out[0] == 0x7E and out[1] == 0xFE
    assert out[2] == 0x7E and out[3] == 0x7E


def test_round_trip_all_finite_codes():
    codes = np.arange(256, dtype=np.uint8)
    vals = np.asarray(fp8.e4m3_decode(codes))
    finite = ~np.isnan(vals)
    back = np.asarray(fp8.e4m3_encode(vals[finite]))
    dec = np.asarray(fp8.e4m3_decode(back))
    np.testing.assert_array_equal(dec, vals[finite])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 8), st.integers(2, 64))
def test_quantize_dequantize_error_bound(seed, rows, cols):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 5, (rows, cols)).astype(np.float32)
    codes, scale = fp8.quantize(x, axis=-1)
    back = np.asarray(fp8.dequantize(codes, scale, axis=-1))
    amax = np.abs(x).max(axis=-1, keepdims=True)
    # e4m3 relative step is 2^-3 of the exponent bucket; after amax
    # scaling the absolute error is bounded by amax/448 * 32 (max step)
    bound = amax * (32.0 / 448.0) + 1e-7
    assert np.all(np.abs(back - x) <= bound)


def test_quantize_zero_slice_safe():
    x = np.zeros((3, 8), np.float32)
    codes, scale = fp8.quantize(x)
    back = np.asarray(fp8.dequantize(codes, scale))
    assert np.all(back == 0)
    assert np.all(np.asarray(scale) > 0)
