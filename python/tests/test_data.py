"""ARC-sim dataset generator: format, seeding, and answer balance."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data
from compile.presets import BOS_ID, EOS_ID, PAD_ID


@pytest.mark.parametrize("split", ["easy", "challenge"])
def test_question_structure(split):
    rng = np.random.default_rng(0)
    kinds = set()
    for _ in range(80):
        q = data.make_question(split, rng)
        kinds.add(q["kind"])
        assert len(q["choices"]) == 4
        assert 0 <= q["answer"] < 4
        if q["kind"] == "arith":
            # the correct answer string sits at the answer slot
            a, b = map(int, q["question"][3:-2].split("+"))
            assert q["choices"][q["answer"]] == str(a + b)
        else:
            # the marker sits on the answer choice, and only there
            letter = data.LETTERS[q["answer"]]
            assert f"{letter}) *" in q["prompt"]
            assert q["prompt"].count("*") == 1
        # distractors differ from the answer
        assert len(set(q["choices"])) == 4
        assert q["prompt"].endswith("Answer:")
        assert q["full"].endswith(data.LETTERS[q["answer"]])
    assert kinds == {"marked", "arith"}, f"both kinds must appear: {kinds}"


def test_split_difficulty_ranges():
    rng = np.random.default_rng(1)
    seen = 0
    while seen < 20:
        qe = data.make_question("easy", rng)
        if qe["kind"] != "arith":
            continue
        a, b = map(int, qe["question"][3:-2].split("+"))
        assert 0 <= a <= 9 and 0 <= b <= 9
        qc = data.make_question("challenge", rng)
        if qc["kind"] == "arith":
            a, b = map(int, qc["question"][3:-2].split("+"))
            assert 10 <= a <= 99 and 10 <= b <= 99
        seen += 1
    # challenge has a lower marked fraction than easy
    assert data.MARKED_FRAC["challenge"] < data.MARKED_FRAC["easy"]


def test_eval_set_seeded_and_balanced():
    s1 = data.make_eval_set("easy", 200, seed=42)
    s2 = data.make_eval_set("easy", 200, seed=42)
    assert s1 == s2, "same seed, same set"
    s3 = data.make_eval_set("easy", 200, seed=43)
    assert s1 != s3
    counts = np.bincount([q["answer"] for q in s1["questions"]], minlength=4)
    assert counts.min() > 20, f"answers unbalanced: {counts}"


def test_encode_decode_round_trip():
    ids = data.encode("Q: 1+2=?", bos=True, eos=True)
    assert ids[0] == BOS_ID and ids[-1] == EOS_ID
    assert data.decode(ids) == "Q: 1+2=?"


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(40, 80), st.integers(0, 2**31 - 1))
def test_training_batch_invariants(batch, seqlen, seed):
    rng = np.random.default_rng(seed)
    toks, lens, w = data.training_batch(["easy", "challenge"], batch, seqlen, rng)
    assert toks.shape == (batch, seqlen)
    assert w.shape == (batch, seqlen)
    for i in range(batch):
        n = lens[i]
        assert 0 < n <= seqlen
        assert toks[i, 0] == BOS_ID
        assert np.all(toks[i, n:] == PAD_ID)
        # weights vanish on padding, answer letter is up-weighted
        assert np.all(w[i, n:] == 0)
        if n >= 4:
            assert w[i, n - 3] > 1.0


def test_write_eval_sets(tmp_path):
    paths = data.write_eval_sets(str(tmp_path), n=10)
    import json

    for split, p in paths.items():
        with open(p) as f:
            loaded = json.load(f)
        assert loaded["split"] == split
        assert len(loaded["questions"]) == 10
