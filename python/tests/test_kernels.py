"""Pallas kernels vs pure-jnp oracles (the core L1 correctness signal).

hypothesis sweeps shapes (batch, heads, groups, block size, context
lengths) and dtypes (f32 cache vs FP8 codes+scales); every property
asserts allclose between the interpret-mode kernel and ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8, kv_write, paged_attention, prefill_attention, ref

RTOL, ATOL = 1e-5, 1e-5


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# kv_write
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 10),   # tokens
    st.sampled_from([1, 2, 4]),   # kv heads
    st.sampled_from([4, 8, 16]),  # block size
    st.booleans(),        # fp8
)
def test_kv_write_matches_ref(seed, T, Hk, BS, use_fp8):
    rng = np.random.default_rng(seed)
    NB, D = 8, 16
    k_new, v_new = rand(rng, T, Hk, D), rand(rng, T, Hk, D)
    total = NB * BS
    # slots: unique, some skipped (-1) — the Eq. 5 filter
    slots = rng.permutation(total)[:T].astype(np.int32)
    skip = rng.random(T) < 0.3
    slots[skip] = -1
    if use_fp8:
        kc = np.zeros((NB, BS, Hk, D), np.uint8)
        vc = np.zeros_like(kc)
        ks = np.full((NB, BS, Hk), 1e-3, np.float32)
        vs = np.full_like(ks, 1e-3)
        out = kv_write.kv_write(jnp.asarray(k_new), jnp.asarray(v_new),
                                jnp.asarray(slots), jnp.asarray(kc),
                                jnp.asarray(vc), jnp.asarray(ks),
                                jnp.asarray(vs))
        want = ref.ref_kv_write(k_new, v_new, slots, kc, vc, ks, vs)
    else:
        kc = np.zeros((NB, BS, Hk, D), np.float32)
        vc = np.zeros_like(kc)
        out = kv_write.kv_write(jnp.asarray(k_new), jnp.asarray(v_new),
                                jnp.asarray(slots), jnp.asarray(kc),
                                jnp.asarray(vc))
        want = ref.ref_kv_write(k_new, v_new, slots, kc, vc)
    for got, exp in zip(out, want):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=RTOL, atol=ATOL)


def test_kv_write_all_skipped_is_noop():
    rng = np.random.default_rng(0)
    kc = rand(rng, 4, 4, 2, 8)
    vc = rand(rng, 4, 4, 2, 8)
    out = kv_write.kv_write(jnp.asarray(rand(rng, 3, 2, 8)),
                            jnp.asarray(rand(rng, 3, 2, 8)),
                            jnp.asarray(np.array([-1, -1, -1], np.int32)),
                            jnp.asarray(kc), jnp.asarray(vc))
    np.testing.assert_array_equal(np.asarray(out[0]), kc)
    np.testing.assert_array_equal(np.asarray(out[1]), vc)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 4),            # batch
    st.sampled_from([1, 2]),      # kv heads
    st.sampled_from([1, 2, 3]),   # groups (Eq. 7)
    st.sampled_from([4, 8]),      # block size
    st.booleans(),                # valid_only (Opt-Pa)
    st.booleans(),                # fp8 (Opt-KV)
)
def test_paged_attention_matches_ref(seed, B, Hk, G, BS, valid_only, use_fp8):
    rng = np.random.default_rng(seed)
    D, MAXB = 16, 5
    NB = B * MAXB + 2
    Hq = Hk * G
    kc = rand(rng, NB, BS, Hk, D)
    vc = rand(rng, NB, BS, Hk, D)
    bt = rng.permutation(NB)[:B * MAXB].reshape(B, MAXB).astype(np.int32)
    ctx = rng.integers(0, MAXB * BS + 1, B).astype(np.int32)
    ctx[0] = max(int(ctx[0]), 1)  # at least one active lane
    q = rand(rng, B, Hq, D)
    if use_fp8:
        kc8, ks = fp8.quantize(kc, axis=-1)
        vc8, vs = fp8.quantize(vc, axis=-1)
        got = paged_attention.paged_attention(
            jnp.asarray(q), kc8, vc8, jnp.asarray(bt), jnp.asarray(ctx),
            ks, vs, groups=G, valid_only=valid_only)
        want = ref.ref_paged_attention(q, np.asarray(kc8), np.asarray(vc8),
                                       bt, ctx, G, np.asarray(ks),
                                       np.asarray(vs))
    else:
        got = paged_attention.paged_attention(
            jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(bt), jnp.asarray(ctx), groups=G,
            valid_only=valid_only)
        want = ref.ref_paged_attention(q, kc, vc, bt, ctx, G)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_paged_attention_valid_only_equals_baseline():
    """Opt-Pa must be a pure optimization: identical numerics."""
    rng = np.random.default_rng(3)
    B, Hk, G, BS, D, MAXB = 3, 2, 2, 8, 16, 4
    NB = 16
    kc, vc = rand(rng, NB, BS, Hk, D), rand(rng, NB, BS, Hk, D)
    bt = rng.permutation(NB)[:B * MAXB].reshape(B, MAXB).astype(np.int32)
    ctx = np.array([5, 17, 32], np.int32)
    q = rand(rng, B, Hk * G, D)
    a = paged_attention.paged_attention(jnp.asarray(q), jnp.asarray(kc),
                                        jnp.asarray(vc), jnp.asarray(bt),
                                        jnp.asarray(ctx), groups=G,
                                        valid_only=True)
    b = paged_attention.paged_attention(jnp.asarray(q), jnp.asarray(kc),
                                        jnp.asarray(vc), jnp.asarray(bt),
                                        jnp.asarray(ctx), groups=G,
                                        valid_only=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_paged_attention_padded_lane_is_zero():
    rng = np.random.default_rng(4)
    kc, vc = rand(rng, 8, 4, 1, 8), rand(rng, 8, 4, 1, 8)
    bt = np.zeros((2, 3), np.int32)
    ctx = np.array([4, 0], np.int32)
    q = rand(rng, 2, 1, 8)
    for vo in (True, False):
        out = np.asarray(paged_attention.paged_attention(
            jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(bt), jnp.asarray(ctx), groups=1, valid_only=vo))
        assert np.all(out[1] == 0), f"valid_only={vo}"


def test_fp8_attention_error_small_but_nonzero():
    """Quantization error must exist (it's real FP8) but stay tiny."""
    rng = np.random.default_rng(5)
    kc, vc = rand(rng, 8, 8, 2, 16), rand(rng, 8, 8, 2, 16)
    bt = np.arange(8, dtype=np.int32).reshape(2, 4)
    ctx = np.array([30, 25], np.int32)
    q = rand(rng, 2, 2, 16)
    exact = np.asarray(paged_attention.paged_attention(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(bt),
        jnp.asarray(ctx), groups=1, valid_only=True))
    kc8, ks = fp8.quantize(kc, axis=-1)
    vc8, vs = fp8.quantize(vc, axis=-1)
    quant = np.asarray(paged_attention.paged_attention(
        jnp.asarray(q), kc8, vc8, jnp.asarray(bt), jnp.asarray(ctx),
        ks, vs, groups=1, valid_only=True))
    err = np.max(np.abs(exact - quant))
    assert 0 < err < 0.05, err


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([8, 16, 32]),  # padded S
    st.sampled_from([1, 2]),       # kv heads
    st.sampled_from([1, 2, 4]),    # groups
)
def test_prefill_attention_matches_ref(seed, S, Hk, G):
    rng = np.random.default_rng(seed)
    D = 16
    Hq = Hk * G
    q, k, v = rand(rng, S, Hq, D), rand(rng, S, Hk, D), rand(rng, S, Hk, D)
    seq_len = int(rng.integers(1, S + 1))
    got = prefill_attention.prefill_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), seq_len, groups=G)
    want = ref.ref_prefill_attention(q, k, v, seq_len, G)
    np.testing.assert_allclose(np.asarray(got)[:seq_len],
                               np.asarray(want)[:seq_len],
                               rtol=1e-4, atol=1e-5)


def test_prefill_causality():
    """Changing future tokens must not change past outputs."""
    rng = np.random.default_rng(6)
    S, Hq, Hk, D = 16, 2, 1, 8
    q, k, v = rand(rng, S, Hq, D), rand(rng, S, Hk, D), rand(rng, S, Hk, D)
    base = np.asarray(prefill_attention.prefill_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), S, groups=2))
    k2, v2 = k.copy(), v.copy()
    k2[10:], v2[10:] = 99.0, -99.0
    pert = np.asarray(prefill_attention.prefill_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), S, groups=2))
    np.testing.assert_allclose(base[:10], pert[:10], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[10:], pert[10:])
