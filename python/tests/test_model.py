"""L2 model tests: serving path (prefill+decode over the paged cache)
must agree with the dense training forward — the end-to-end numerical
contract between L1/L2 and what the rust engine will see."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.presets import MODELS, OPT_CONFIGS, PAD_ID, weight_shapes, weight_names


def tiny_preset():
    # smallest zoo member keeps tests fast
    return MODELS["llama-7b-sim"]


def make_caches(preset, opt, NB=16, BS=4):
    hk = preset.n_kv_heads(opt.gqa)
    shape = (preset.layers, NB, BS, hk, preset.head_dim)
    if opt.fp8_kv:
        kc = jnp.zeros(shape, jnp.uint8)
        vc = jnp.zeros(shape, jnp.uint8)
        ks = jnp.full(shape[:-1], 1e-6, jnp.float32)
        vs = jnp.full(shape[:-1], 1e-6, jnp.float32)
        return (kc, vc, ks, vs)
    return (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))


def serving_logits(preset, opt, params, tokens, S=16, NB=16, BS=4, MAXB=4):
    """Run prompt through prefill, then decode the rest token by token;
    returns the last-position logits after consuming all `tokens`."""
    prompt = tokens[: len(tokens) - len(tokens) // 2]
    rest = tokens[len(prompt):]
    caches = make_caches(preset, opt, NB, BS)

    padded = np.full(S, PAD_ID, np.int32)
    padded[: len(prompt)] = prompt
    slot_map = np.full(S, -1, np.int32)
    for i in range(len(prompt) if opt.skip_filter else S):
        slot_map[i] = i  # identity layout: blocks 0..S/BS
    out = M.forward_prefill(params, preset, opt, jnp.asarray(padded),
                            jnp.int32(len(prompt)), jnp.asarray(slot_map),
                            *caches)
    logits, caches = out[0], out[1:]
    last = np.asarray(logits)[len(prompt) - 1]

    bt = np.zeros((1, MAXB), np.int32)
    bt[0, :] = np.arange(MAXB)
    for i, tok in enumerate(rest):
        pos = len(prompt) + i
        out = M.forward_decode(
            params, preset, opt,
            jnp.asarray(np.array([tok], np.int32)),
            jnp.asarray(np.array([pos], np.int32)),
            jnp.asarray(bt),
            jnp.asarray(np.array([pos + 1], np.int32)),
            jnp.asarray(np.array([pos], np.int32)),  # slot = position
            *caches)
        logits, caches = out[0], out[1:]
        last = np.asarray(logits)[0]
    return last


@pytest.mark.parametrize("cfg", ["original", "optpa", "optgqa", "coopt"])
def test_serving_path_matches_dense(cfg):
    preset = tiny_preset()
    opt = OPT_CONFIGS[cfg]
    params = M.init_params(preset, seed=1)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 255, 12).astype(np.int32).tolist()

    got = serving_logits(preset, opt, params, tokens)
    toks = np.asarray([tokens], np.int32)
    lens = np.asarray([len(tokens)], np.int32)
    dense = np.asarray(
        M.forward_train(params, preset, jnp.asarray(toks), jnp.asarray(lens),
                        gqa=opt.gqa))[0, len(tokens) - 1]
    # FP8 per-slot quantization error compounds over the decoded suffix;
    # bound it loosely here (test_fp8_serving_close_to_dense checks the
    # argmax survives, which is what serving correctness needs)
    tol = 1e-1 if opt.fp8_kv else 1e-3
    np.testing.assert_allclose(got, dense, rtol=tol, atol=tol)


def test_fp8_serving_close_to_dense():
    """coopt (FP8 cache) must track dense logits within quantization noise
    and must preserve the argmax on a confident distribution."""
    preset = tiny_preset()
    opt = OPT_CONFIGS["coopt"]
    params = M.init_params(preset, seed=2)
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, 255, 10).astype(np.int32).tolist()
    got = serving_logits(preset, opt, params, tokens)
    dense = np.asarray(
        M.forward_train(params, preset,
                        jnp.asarray(np.asarray([tokens], np.int32)),
                        jnp.asarray(np.asarray([len(tokens)], np.int32)),
                        gqa=True))[0, len(tokens) - 1]
    # bounded error
    assert np.max(np.abs(got - dense)) < 0.2
    # rank correlation of top tokens survives quantization
    assert np.argmax(got) == np.argmax(dense)


def test_weight_shapes_cover_names():
    for preset in MODELS.values():
        shapes = weight_shapes(preset)
        names = weight_names(preset)
        assert set(shapes) == set(names)
        assert names[0] == "embed" and names[-1] == "lm_head"


def test_init_params_match_declared_shapes():
    preset = tiny_preset()
    params = M.init_params(preset)
    for name, shape in weight_shapes(preset).items():
        assert tuple(params[name].shape) == tuple(shape), name


def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1, 6, 2, 32)).astype(np.float32)
    pos = np.arange(6, dtype=np.int32)[None]
    y = np.asarray(M.rope(jnp.asarray(x), jnp.asarray(pos)))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(y[:, 0], x[:, 0], rtol=1e-6)


def test_rms_norm_scale_invariance():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 16)).astype(np.float32)
    w = np.ones(16, np.float32)
    a = np.asarray(M.rms_norm(jnp.asarray(x), jnp.asarray(w)))
    b = np.asarray(M.rms_norm(jnp.asarray(x * 10), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=1e-4)
