"""AOT lowering driver: jax (L2+L1) -> HLO text artifacts for the rust runtime.

Emits, per (model, opt-config):

    artifacts/<model>_<cfg>_prefill.hlo.txt
    artifacts/<model>_<cfg>_decode.hlo.txt

plus per model `<model>.weights.bin` (raw little-endian f32, canonical
order), the ARC-sim eval sets, and `manifest.json` describing every
graph's exact parameter list (name, dtype, shape) so the rust runtime can
feed PJRT buffers positionally.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

`--report` prints the L1 VMEM-footprint / MXU-utilization estimate used
by EXPERIMENTS.md §Perf (interpret=True gives no TPU timings; structure
is what we can and do optimize).

Run from python/:  python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .model import forward_decode, forward_prefill, init_params
from .presets import (BLOCK_SIZE, MAX_BATCH, MAX_BLOCKS, MAX_SEQ,
                      NUM_POOL_BLOCKS, MODELS, OPT_CONFIGS,
                      graph_weight_names, preset_dict, weight_names,
                      weight_shapes)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def cache_shapes(preset, opt):
    hk = preset.n_kv_heads(opt.gqa)
    kv = (preset.layers, NUM_POOL_BLOCKS, BLOCK_SIZE, hk, preset.head_dim)
    sc = (preset.layers, NUM_POOL_BLOCKS, BLOCK_SIZE, hk)
    dtype = "u8" if opt.fp8_kv else "f32"
    shapes = [("k_cache", dtype, kv), ("v_cache", dtype, kv)]
    if opt.fp8_kv:
        shapes += [("k_scale", "f32", sc), ("v_scale", "f32", sc)]
    return shapes


def runtime_inputs(preset, opt, phase):
    if phase == "prefill":
        base = [("token_ids", "i32", (MAX_SEQ,)),
                ("seq_len", "i32", (1,)),
                ("slot_mapping", "i32", (MAX_SEQ,))]
    else:
        base = [("token_ids", "i32", (MAX_BATCH,)),
                ("positions", "i32", (MAX_BATCH,)),
                ("block_tables", "i32", (MAX_BATCH, MAX_BLOCKS)),
                ("ctx_lens", "i32", (MAX_BATCH,)),
                ("slot_mapping", "i32", (MAX_BATCH,))]
    return base + cache_shapes(preset, opt)


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u8": jnp.uint8}


def _specs(entries):
    return [jax.ShapeDtypeStruct(shape, _DTYPES[dt]) for _, dt, shape in entries]


def build_fn(preset, opt, phase):
    # only the weights the graph references (XLA DCEs unused parameters,
    # so feeding the full checkpoint would mismatch the compiled arity)
    names = graph_weight_names(preset, opt.gqa)

    if phase == "prefill":
        def fn(weights, token_ids, seq_len, slot_mapping, *caches):
            params = dict(zip(names, weights))
            return forward_prefill(params, preset, opt, token_ids,
                                   seq_len[0], slot_mapping, *caches)
    else:
        def fn(weights, token_ids, positions, block_tables, ctx_lens,
               slot_mapping, *caches):
            params = dict(zip(names, weights))
            return forward_decode(params, preset, opt, token_ids, positions,
                                  block_tables, ctx_lens, slot_mapping,
                                  *caches)
    return fn


def lower_graph(preset, opt, phase):
    shapes = weight_shapes(preset)
    names = graph_weight_names(preset, opt.gqa)
    w_specs = tuple(jax.ShapeDtypeStruct(shapes[n], jnp.float32)
                    for n in names)
    rt = runtime_inputs(preset, opt, phase)
    fn = build_fn(preset, opt, phase)
    lowered = jax.jit(fn).lower(w_specs, *_specs(rt))
    txt = to_hlo_text(lowered)
    # count parameters of the ENTRY computation only (nested while/fusion
    # computations carry their own parameter instructions)
    n_params = txt.split("ENTRY", 1)[1].count(" parameter(")
    want = len(names) + len(rt)
    assert n_params == want, (
        f"{preset.name}/{opt.name}/{phase}: compiled graph has {n_params} "
        f"parameters, manifest expects {want} (XLA DCE mismatch)")
    return txt


# ---------------------------------------------------------------------------
# weights serialization
# ---------------------------------------------------------------------------

def write_weights_bin(preset, params, path):
    """Raw little-endian f32, canonical `weight_names` order."""
    offsets = {}
    off = 0
    with open(path, "wb") as f:
        for name in weight_names(preset):
            arr = np.ascontiguousarray(np.asarray(params[name], np.float32))
            want = weight_shapes(preset)[name]
            assert tuple(arr.shape) == tuple(want), (name, arr.shape, want)
            b = arr.astype("<f4").tobytes()
            f.write(b)
            offsets[name] = {"offset": off, "nbytes": len(b),
                             "shape": list(arr.shape)}
            off += len(b)
    return offsets


def load_or_init_params(preset, weights_dir, log):
    path = os.path.join(weights_dir, f"{preset.name}.npz")
    if os.path.exists(path):
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    log(f"  !! no trained weights at {path}; using random init "
        f"(run `python -m compile.train` first for real accuracy numbers)")
    return {k: np.asarray(v) for k, v in init_params(preset).items()}


# ---------------------------------------------------------------------------
# §Perf: L1 structural report (VMEM footprint / MXU shapes)
# ---------------------------------------------------------------------------

def l1_report(preset, opt):
    """Estimate per-program VMEM bytes + MXU tile utilization for the paged
    decode kernel (interpret mode has no TPU timings; see DESIGN.md §5)."""
    hd = preset.head_dim
    hk = preset.n_kv_heads(opt.gqa)
    g = preset.n_heads // hk
    kv_elt = 1 if opt.fp8_kv else 4
    q_tile = g * hd * 4
    kv_tile = BLOCK_SIZE * hd * kv_elt * 2          # K and V tiles
    scale_tile = (BLOCK_SIZE * 4 * 2) if opt.fp8_kv else 0
    acc = (g * hd + 2 * g) * 4                      # acc, m, l
    score = g * BLOCK_SIZE * 4
    vmem = q_tile + kv_tile + scale_tile + acc + score
    # MXU: contraction is [g, hd] x [hd, BS]; systolic array is 128x128,
    # lanes pad to (8, 128) — utilization of the padded tile:
    mxu_rows = max(8, g)
    mxu_cols = 128
    util = (g * BLOCK_SIZE) / (mxu_rows * mxu_cols)
    return {"vmem_bytes_per_program": vmem,
            "vmem_double_buffered": vmem + kv_tile + scale_tile,
            "mxu_tile_utilization": round(util, 4),
            "query_group": g, "kv_heads": hk}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def write_golden(outdir, weights_dir, model_name="llama-7b-sim"):
    """Cross-language fixture: run a canned prompt through the python
    serving path (prefill + 2 decode steps) for every opt config and store
    the resulting logits rows.  rust/tests/integration_runtime.rs replays
    the same steps through PJRT and asserts allclose — an end-to-end
    L1+L2+runtime equivalence test."""
    import jax

    preset = MODELS[model_name]
    params = load_or_init_params(preset, weights_dir, print)
    params = {k: jnp.asarray(v) for k, v in params.items()}
    from . import data as D
    from .model import forward_decode, forward_prefill

    prompt = D.encode("Q: 4+5=? A) 9 B) 8 C) 7 D) 6\nAnswer:", bos=True)
    t = len(prompt)
    golden = {"model": model_name, "prompt_tokens": prompt, "configs": {}}
    for opt in OPT_CONFIGS.values():
        hk = preset.n_kv_heads(opt.gqa)
        L = preset.layers
        kv_shape = (L, NUM_POOL_BLOCKS, BLOCK_SIZE, hk, preset.head_dim)
        sc_shape = (L, NUM_POOL_BLOCKS, BLOCK_SIZE, hk)
        if opt.fp8_kv:
            caches = (jnp.zeros(kv_shape, jnp.uint8), jnp.zeros(kv_shape, jnp.uint8),
                      jnp.full(sc_shape, 1e-6, jnp.float32), jnp.full(sc_shape, 1e-6, jnp.float32))
        else:
            caches = (jnp.zeros(kv_shape, jnp.float32), jnp.zeros(kv_shape, jnp.float32))
        toks = np.full(MAX_SEQ, 256, np.int32)
        toks[:t] = prompt
        slots = np.full(MAX_SEQ, -1, np.int32)
        upto = t if opt.skip_filter else MAX_SEQ
        slots[:upto] = np.arange(upto)
        out = forward_prefill(params, preset, opt, jnp.asarray(toks),
                              jnp.int32(t), jnp.asarray(slots), *caches)
        logits, caches = out[0], out[1:]
        rows = {"prefill_last": np.asarray(logits)[t - 1].tolist()}
        # two greedy decode steps
        bt = np.zeros((MAX_BATCH, MAX_BLOCKS), np.int32)
        bt[0, :] = np.arange(MAX_BLOCKS)
        tok = int(np.argmax(np.asarray(logits)[t - 1]))
        decode_rows = []
        for step in range(2):
            pos = t + step
            token_ids = np.full(MAX_BATCH, 256, np.int32)
            token_ids[0] = tok
            positions = np.zeros(MAX_BATCH, np.int32)
            positions[0] = pos
            ctx = np.zeros(MAX_BATCH, np.int32)
            ctx[0] = pos + 1
            sm = np.full(MAX_BATCH, -1, np.int32)
            sm[0] = pos
            out = forward_decode(params, preset, opt,
                                 jnp.asarray(token_ids), jnp.asarray(positions),
                                 jnp.asarray(bt), jnp.asarray(ctx),
                                 jnp.asarray(sm), *caches)
            logits, caches = out[0], out[1:]
            row = np.asarray(logits)[0]
            decode_rows.append({"token": tok, "position": pos,
                                "logits": row.tolist()})
            tok = int(np.argmax(row))
        rows["decode_steps"] = decode_rows
        golden["configs"][opt.name] = rows
        print(f"golden: {model_name}/{opt.name} done", flush=True)
    with open(os.path.join(outdir, "golden.json"), "w") as f:
        json.dump(golden, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--configs", default=",".join(OPT_CONFIGS))
    ap.add_argument("--weights-dir", default=None,
                    help="default: <out>/weights")
    ap.add_argument("--report", action="store_true",
                    help="print the L1 VMEM/MXU structural report and exit")
    ap.add_argument("--golden-only", action="store_true",
                    help="only (re)write the golden.json fixture")
    args = ap.parse_args()

    if args.golden_only:
        wd = args.weights_dir or os.path.join(args.out, "weights")
        write_golden(args.out, wd)
        return

    models = [MODELS[m] for m in args.models.split(",")]
    configs = [OPT_CONFIGS[c] for c in args.configs.split(",")]

    if args.report:
        for preset in models:
            for opt in configs:
                print(f"{preset.name:18s} {opt.name:9s} {l1_report(preset, opt)}")
        return

    os.makedirs(args.out, exist_ok=True)
    weights_dir = args.weights_dir or os.path.join(args.out, "weights")
    manifest = {
        "version": 1,
        "block_size": BLOCK_SIZE,
        "max_blocks": MAX_BLOCKS,
        "num_pool_blocks": NUM_POOL_BLOCKS,
        "max_batch": MAX_BATCH,
        "max_seq": MAX_SEQ,
        "models": {},
        "configs": {c.name: vars(c) for c in configs},
        "graphs": [],
    }

    for preset in models:
        t0 = time.time()
        params = load_or_init_params(preset, weights_dir, print)
        wpath = os.path.join(args.out, f"{preset.name}.weights.bin")
        offsets = write_weights_bin(preset, params, wpath)
        md = preset_dict(preset)
        md["weights_file"] = os.path.basename(wpath)
        md["weights"] = [
            {"name": n, **offsets[n]} for n in weight_names(preset)]
        manifest["models"][preset.name] = md

        for opt in configs:
            for phase in ("prefill", "decode"):
                fname = f"{preset.name}_{opt.name}_{phase}.hlo.txt"
                txt = lower_graph(preset, opt, phase)
                with open(os.path.join(args.out, fname), "w") as f:
                    f.write(txt)
                rt = runtime_inputs(preset, opt, phase)
                n_out = 5 if opt.fp8_kv else 3
                manifest["graphs"].append({
                    "model": preset.name,
                    "config": opt.name,
                    "phase": phase,
                    "file": fname,
                    "weights": graph_weight_names(preset, opt.gqa),
                    "runtime_inputs": [
                        {"name": n, "dtype": dt, "shape": list(s)}
                        for n, dt, s in rt],
                    "num_outputs": n_out,
                    "l1_report": l1_report(preset, opt),
                })
        print(f"[{preset.name}] lowered {2 * len(configs)} graphs "
              f"in {time.time() - t0:.1f}s", flush=True)

    eval_paths = data.write_eval_sets(args.out)
    manifest["eval_sets"] = {k: os.path.basename(v)
                             for k, v in eval_paths.items()}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json "
          f"({len(manifest['graphs'])} graphs)")
    write_golden(args.out, weights_dir)


if __name__ == "__main__":
    main()
