"""From-scratch training of the five sim models (build path only).

Reproduces the checkpoint lineage the paper assumes:

  1. pretrain each sim model with dense MHA on the synthetic QA corpus;
  2. derive GQA weights by mean-pooling KV projection head groups and
     briefly uptraining (Ainslie et al., GQA — the checkpoints the paper's
     Opt-GQA serves were produced this way);
  3. apply GPTQ-style group-wise 4-bit weight quantization (round-to-
     nearest; the paper's models are *-GPTQ) to both weight sets.

Output: artifacts/weights/<model>.npz + a training log.  Step budgets are
scaled by model capacity so that, like the paper's zoo, bigger sims score
higher on the ARC-sim splits without saturating.

Run: python -m compile.train [--models a,b] [--steps N] [--out DIR]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import forward_train, init_params
from .presets import MODELS, PAD_ID, VOCAB_SIZE

SEQLEN = 64
BATCH = 32


# ---------------------------------------------------------------------------
# hand-rolled Adam (optax is not available in this environment)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def loss_fn(params, preset, toks, lens, w, *, gqa):
    logits = forward_train(params, preset, toks[:, :-1], lens, gqa=gqa)
    targets = toks[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    wt = w[:, : targets.shape[1]]
    return jnp.sum(nll * wt) / jnp.maximum(jnp.sum(wt), 1.0)


def train_model(preset, *, steps, uptrain_steps, lr, seed, log):
    rng = np.random.default_rng(seed)
    params = init_params(preset, seed=seed)
    splits = ["easy", "challenge"]

    @jax.jit
    def step_mha(params, opt_state, toks, lens, w):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, preset, toks, lens, w, gqa=False))(params)
        params, opt_state = adam_update(params, grads, opt_state, lr)
        return params, opt_state, loss

    opt_state = adam_init(params)
    t0 = time.time()
    for i in range(steps):
        toks, lens, w = data.training_batch(splits, BATCH, SEQLEN, rng)
        params, opt_state, loss = step_mha(params, opt_state,
                                           jnp.asarray(toks),
                                           jnp.asarray(lens), jnp.asarray(w))
        if i % 50 == 0 or i == steps - 1:
            msg = f"[{preset.name}] mha step {i}/{steps} loss {float(loss):.4f}"
            print(msg, flush=True)
            log.append(msg)

    # --- continue MHA training for `uptrain_steps` so the MHA and GQA
    # branches receive equal total optimization (otherwise the GQA
    # uptraining would add net capability and the accuracy tables would
    # compare different-quality checkpoints instead of serving paths)
    for i in range(uptrain_steps):
        toks, lens, w = data.training_batch(splits, BATCH, SEQLEN, rng)
        params, opt_state, loss = step_mha(params, opt_state,
                                           jnp.asarray(toks),
                                           jnp.asarray(lens), jnp.asarray(w))
    log.append(f"[{preset.name}] mha continuation done loss {float(loss):.4f}")

    # --- GQA derivation: mean-pool KV projection head groups, then uptrain.
    hd = preset.head_dim
    hq, hk = preset.n_heads, preset.n_kv_heads_gqa
    g = hq // hk
    for i in range(preset.layers):
        for kind in ("wk", "wv"):
            w_mha = params[f"l{i}.{kind}_mha"]  # [d, Hq*hd]
            d = w_mha.shape[0]
            pooled = w_mha.reshape(d, hk, g, hd).mean(axis=2).reshape(d, hk * hd)
            params[f"l{i}.{kind}_gqa"] = pooled

    @jax.jit
    def step_gqa(params, opt_state, toks, lens, w):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, preset, toks, lens, w, gqa=True))(params)
        params, opt_state = adam_update(params, grads, opt_state, lr * 0.5)
        return params, opt_state, loss

    opt_state = adam_init(params)
    for i in range(uptrain_steps):
        toks, lens, w = data.training_batch(splits, BATCH, SEQLEN, rng)
        params, opt_state, loss = step_gqa(params, opt_state,
                                           jnp.asarray(toks),
                                           jnp.asarray(lens), jnp.asarray(w))
        if i % 50 == 0 or i == uptrain_steps - 1:
            msg = (f"[{preset.name}] gqa-uptrain step {i}/{uptrain_steps} "
                   f"loss {float(loss):.4f}")
            print(msg, flush=True)
            log.append(msg)
    log.append(f"[{preset.name}] trained in {time.time() - t0:.1f}s")
    return params


# ---------------------------------------------------------------------------
# GPTQ-style 4-bit round-to-nearest group quantization
# ---------------------------------------------------------------------------

def gptq_rtn_int4(w, group=32):
    """Group-wise symmetric int4 RTN over the input dimension.

    (True GPTQ adds Hessian-ordered error compensation; RTN int4 captures
    the serving-relevant property — 4-bit weight error — which is what the
    accuracy tables must survive.  Documented in DESIGN.md.)
    """
    w = np.asarray(w, np.float32)
    if w.ndim != 2:
        return w
    rows, cols = w.shape
    pad = (-rows) % group
    if pad:
        w = np.concatenate([w, np.zeros((pad, cols), np.float32)], 0)
    wg = w.reshape(-1, group, cols)
    scale = np.maximum(np.abs(wg).max(axis=1, keepdims=True), 1e-8) / 7.0
    q = np.clip(np.round(wg / scale), -8, 7)
    deq = (q * scale).reshape(-1, cols)[:rows]
    return deq


def quantize_params(params, group=32):
    out = {}
    for name, w in params.items():
        w = np.asarray(w)
        if w.ndim == 2 and not name.startswith("embed"):
            out[name] = gptq_rtn_int4(w, group)
        else:
            out[name] = w
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

# capacity-scaled step budgets: bigger sims train longer (like bigger
# pretrained models having more capability), nobody saturates
STEP_BUDGET = {
    # chosen relative to the induction-circuit acquisition transition
    # (~200 steps at batch 32 on this corpus): the 7B-class sims stop
    # short of it (near-chance tables, like the paper's 27-30% 7B ARC
    # scores), the 13B-class sims train well past it (mid-range scores)
    "llama-7b-sim": (120, 45),
    "llama2-7b-sim": (155, 50),
    "llama-13b-sim": (300, 90),
    "llama2-13b-sim": (340, 100),
    "llama-pro-8b-sim": (200, 60),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--steps", type=int, default=0,
                    help="override base steps for every model (testing)")
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    log = []
    for name in args.models.split(","):
        preset = MODELS[name]
        steps, up = STEP_BUDGET[name]
        if args.steps:
            steps, up = args.steps, max(args.steps // 3, 1)
        params = train_model(preset, steps=steps, uptrain_steps=up,
                             lr=3e-3, seed=args.seed, log=log)
        qparams = quantize_params(params)
        path = os.path.join(args.out, f"{name}.npz")
        np.savez(path, **qparams)
        print(f"wrote {path}")
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
