"""Model and optimization-config presets shared by train/aot/tests.

The five sim models stand in for the paper's five GPTQ checkpoints
(LLaMa-7B, LLaMa2-7B, LLaMa-13B, LLaMa2-13B, LLaMa-Pro-8B); see
DESIGN.md for the substitution rationale.  All use head_dim 32 and a
byte-level vocab so the rust tokenizer is trivial to mirror.
"""

from dataclasses import dataclass, field, asdict

# Byte-level tokenizer: 256 raw bytes + specials.
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 260  # 256 bytes + PAD/BOS/EOS + 1 spare

HEAD_DIM = 32
BLOCK_SIZE = 16      # paged-KV block size B (Eq. 9)
MAX_BLOCKS = 10      # per-sequence block-table width -> max ctx 160
NUM_POOL_BLOCKS = 96 # global paged pool
MAX_BATCH = 8        # decode batch (padded)
MAX_SEQ = 128        # prefill length (padded)
FP8_MAX = 448.0      # e4m3fn max finite


@dataclass(frozen=True)
class ModelPreset:
    name: str
    stands_for: str
    layers: int
    d_model: int
    n_heads: int          # H_q
    n_kv_heads_gqa: int   # H_k when Opt-GQA is on (MHA otherwise)
    ffn: int
    # paper-scale twin (drives the Z100 traffic model on the rust side)
    paper_layers: int
    paper_d_model: int
    paper_heads: int
    vocab: int = VOCAB_SIZE
    head_dim: int = HEAD_DIM

    @property
    def hidden(self) -> int:
        return self.n_heads * self.head_dim

    def n_kv_heads(self, gqa: bool) -> int:
        return self.n_kv_heads_gqa if gqa else self.n_heads


MODELS = {
    m.name: m
    for m in [
        ModelPreset("llama-7b-sim", "LLaMa-7B-GPTQ", 3, 128, 4, 2, 352, 32, 4096, 32),
        ModelPreset("llama2-7b-sim", "LLaMa2-7B-GPTQ", 3, 128, 4, 2, 384, 32, 4096, 32),
        ModelPreset("llama-13b-sim", "LLaMa-13B-GPTQ", 4, 192, 6, 2, 512, 40, 5120, 40),
        ModelPreset("llama2-13b-sim", "LLaMa2-13B-GPTQ", 4, 192, 6, 2, 544, 40, 5120, 40),
        ModelPreset("llama-pro-8b-sim", "LLaMa-Pro-8B-GPTQ", 4, 160, 5, 1, 448, 40, 4096, 32),
    ]
}


@dataclass(frozen=True)
class OptConfig:
    """Which of the paper's three optimizations are active.

    original : vLLM baseline (FP16 KV, MHA, touches every block)
    optkv    : Opt-KV  (FP8 cache + SkipSet write filter)  §3.1
    optgqa   : Opt-GQA (grouped-query attention)           §3.2
    optpa    : Opt-Pa  (valid-block-only paged attention)  §3.3
    coopt    : all three (LLM-CoOpt)
    """

    name: str
    fp8_kv: bool      # Opt-KV read path: cache stored as e4m3 codes + scales
    skip_filter: bool # Opt-KV write path: engine emits -1 slots for SkipSet
    gqa: bool         # Opt-GQA: H_k = n_kv_heads_gqa instead of n_heads
    valid_only: bool  # Opt-Pa: attention loops ceil(t/B) blocks, not MAX_BLOCKS


OPT_CONFIGS = {
    c.name: c
    for c in [
        OptConfig("original", False, False, False, False),
        OptConfig("optkv", True, True, False, False),
        OptConfig("optgqa", False, False, True, False),
        OptConfig("optpa", False, False, False, True),
        OptConfig("coopt", True, True, True, True),
    ]
}


def weight_names(preset: ModelPreset) -> list:
    """Canonical flat ordering of weight arrays (shared with rust manifest)."""
    names = ["embed"]
    for i in range(preset.layers):
        names += [
            f"l{i}.attn_norm",
            f"l{i}.wq",
            f"l{i}.wk_mha",
            f"l{i}.wv_mha",
            f"l{i}.wk_gqa",
            f"l{i}.wv_gqa",
            f"l{i}.wo",
            f"l{i}.ffn_norm",
            f"l{i}.w1",
            f"l{i}.w2",
            f"l{i}.w3",
        ]
    names += ["final_norm", "lm_head"]
    return names


def graph_weight_names(preset: ModelPreset, gqa: bool) -> list:
    """Weights actually referenced by a lowered graph.

    The checkpoint carries both KV projection sets, but XLA's
    stablehlo->HLO conversion dead-code-eliminates unused parameters, so
    each graph must be fed exactly the set its config reads (the manifest
    records this list per graph for the rust runtime).
    """
    drop = "_mha" if gqa else "_gqa"
    return [n for n in weight_names(preset) if not n.endswith(drop)]


def weight_shapes(preset: ModelPreset) -> dict:
    """name -> shape for every weight array (both MHA and GQA projections).

    We carry both KV projection sets in one checkpoint so a single weights
    file serves all five opt configs; the lowered graph only references the
    set its config needs (XLA DCEs the other, and the rust runtime feeds
    parameters by manifest order).
    """
    p = preset
    d, hd = p.d_model, p.head_dim
    shapes = {"embed": (p.vocab, d)}
    for i in range(p.layers):
        shapes[f"l{i}.attn_norm"] = (d,)
        shapes[f"l{i}.wq"] = (d, p.n_heads * hd)
        shapes[f"l{i}.wk_mha"] = (d, p.n_heads * hd)
        shapes[f"l{i}.wv_mha"] = (d, p.n_heads * hd)
        shapes[f"l{i}.wk_gqa"] = (d, p.n_kv_heads_gqa * hd)
        shapes[f"l{i}.wv_gqa"] = (d, p.n_kv_heads_gqa * hd)
        shapes[f"l{i}.wo"] = (p.n_heads * hd, d)
        shapes[f"l{i}.ffn_norm"] = (d,)
        shapes[f"l{i}.w1"] = (d, p.ffn)
        shapes[f"l{i}.w2"] = (p.ffn, d)
        shapes[f"l{i}.w3"] = (d, p.ffn)
    shapes["final_norm"] = (d,)
    shapes["lm_head"] = (d, p.vocab)
    return shapes


def preset_dict(preset: ModelPreset) -> dict:
    d = asdict(preset)
    d["block_size"] = BLOCK_SIZE
    d["max_blocks"] = MAX_BLOCKS
    d["num_pool_blocks"] = NUM_POOL_BLOCKS
    d["max_batch"] = MAX_BATCH
    d["max_seq"] = MAX_SEQ
    return d
