"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These implement the same math as the kernels with ordinary gather/softmax
jnp code — no paging tricks, no online softmax — so any disagreement is a
kernel bug.  pytest (python/tests/) sweeps shapes/dtypes via hypothesis and
asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp
import numpy as np

from . import fp8


def ref_kv_write(k_new, v_new, slot_mapping, k_cache, v_cache,
                 k_scale=None, v_scale=None):
    """Oracle for the Opt-KV write kernel (Alg. 1 phase 1).

    k_new/v_new: [T, Hk, D] f32; slot_mapping: [T] i32 (-1 = skip, Eq. 5).
    Caches: [NB, BS, Hk, D]; fp8 mode iff scales are given
    (then caches are uint8 codes and scales are [NB, BS, Hk] f32).
    """
    k_cache = np.array(k_cache)
    v_cache = np.array(v_cache)
    fp8_mode = k_scale is not None
    if fp8_mode:
        k_scale = np.array(k_scale)
        v_scale = np.array(v_scale)
    bs = k_cache.shape[1]
    for t in range(k_new.shape[0]):
        slot = int(slot_mapping[t])
        if slot < 0:
            continue
        b, o = slot // bs, slot % bs
        if fp8_mode:
            kc, ks = fp8.quantize(k_new[t], axis=-1)
            vc, vs = fp8.quantize(v_new[t], axis=-1)
            k_cache[b, o] = np.asarray(kc)
            v_cache[b, o] = np.asarray(vc)
            k_scale[b, o] = np.asarray(ks)
            v_scale[b, o] = np.asarray(vs)
        else:
            k_cache[b, o] = np.asarray(k_new[t])
            v_cache[b, o] = np.asarray(v_new[t])
    out = (jnp.asarray(k_cache), jnp.asarray(v_cache))
    if fp8_mode:
        out += (jnp.asarray(k_scale), jnp.asarray(v_scale))
    return out


def gather_kv(seq_idx, ctx_len, block_table, k_cache, v_cache,
              k_scale=None, v_scale=None):
    """Gather a sequence's [ctx, Hk, D] K/V from the paged pool
    (the `gather_cached_kv` reference, Eq. 6 dequant included)."""
    bs = k_cache.shape[1]
    ks, vs = [], []
    for pos in range(int(ctx_len)):
        b = int(block_table[seq_idx, pos // bs])
        o = pos % bs
        if k_scale is not None:
            ks.append(fp8.dequantize(k_cache[b, o], k_scale[b, o], axis=-1))
            vs.append(fp8.dequantize(v_cache[b, o], v_scale[b, o], axis=-1))
        else:
            ks.append(k_cache[b, o])
            vs.append(v_cache[b, o])
    return jnp.stack(ks), jnp.stack(vs)


def ref_paged_attention(q, k_cache, v_cache, block_tables, ctx_lens,
                        groups, k_scale=None, v_scale=None):
    """Oracle for the paged decode attention (Alg. 3 + Eq. 7/8/10).

    q: [B, Hq, D]; caches [NB, BS, Hk, D]; returns [B, Hq, D].
    Query head i attends through KV head i // groups (Eq. 7).
    Rows with ctx_lens == 0 return zeros (padded batch slots).
    """
    q = jnp.asarray(q, jnp.float32)
    B, Hq, D = q.shape
    out = np.zeros((B, Hq, D), np.float32)
    for b in range(B):
        t = int(ctx_lens[b])
        if t == 0:
            continue
        k, v = gather_kv(b, t, block_tables, k_cache, v_cache, k_scale, v_scale)
        for h in range(Hq):
            hk = h // groups
            s = (q[b, h] @ k[:, hk, :].T) / jnp.sqrt(jnp.float32(D))
            p = jnp.exp(s - jnp.max(s))
            p = p / jnp.sum(p)
            out[b, h] = np.asarray(p @ v[:, hk, :])
    return jnp.asarray(out)


def ref_prefill_attention(q, k, v, seq_len, groups):
    """Oracle for causal grouped prefill attention.

    q: [S, Hq, D], k/v: [S, Hk, D]; positions >= seq_len are masked out of
    the keys; returns [S, Hq, D] (rows >= seq_len are unspecified-but-finite).
    """
    q = jnp.asarray(q, jnp.float32)
    S, Hq, D = q.shape
    pos = jnp.arange(S)
    outs = []
    for h in range(Hq):
        hk = h // groups
        s = (q[:, h, :] @ k[:, hk, :].T) / jnp.sqrt(jnp.float32(D))
        mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] < seq_len)
        s = jnp.where(mask, s, -1e30)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s)
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        outs.append(p @ v[:, hk, :])
    return jnp.stack(outs, axis=1)


def ref_dense_causal_attention(q, k, v, lens=None):
    """Batched dense causal MHA/GQA used by the trainer.

    q: [B, S, Hq, D], k/v: [B, S, Hk, D]; lens: [B] optional valid lengths.
    """
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    groups = Hq // Hk
    kx = jnp.repeat(k, groups, axis=2)
    vx = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, kx) / jnp.sqrt(jnp.float32(D))
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if lens is not None:
        mask = mask[None, :, :] & (pos[None, None, :] < lens[:, None, None])
        mask = mask[:, None, :, :]
    else:
        mask = mask[None, None, :, :]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhst,bthd->bshd", p, vx)
