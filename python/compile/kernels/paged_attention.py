"""Fused paged decode-attention kernel — the paper's hot spot.

One Pallas program per (sequence, kv-head).  The program pulls its query
group ([H_g, D], Eq. 7) into VMEM, then walks the sequence's KV blocks via
the block table with an online-softmax accumulator (Eq. 8/10):

  * **Opt-Pa** (`valid_only=True`): the block loop is bounded by
    ceil(ctx/B) — only valid blocks are touched (Eq. 9).  The baseline
    walks *all* MAX_BLOCKS table entries (vLLM-on-Z100 behaviour the paper
    criticizes: "all KVs being loaded into memory regardless of whether
    they are actually useful"), masking scores to keep numerics identical.
  * **Opt-KV** (`fp8=True`): KV tiles are uint8 E4M3 codes, dequantized
    in-register against per-slot scales before the q·Kᵀ contraction
    (Eq. 6, the `gather_cached_kv` read path).
  * **Opt-GQA** (`groups>1`): the H_g query heads of a group share the
    program's KV head, so each KV tile is fetched once per group rather
    than once per query head.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's `block_sum`
shared-memory reduction maps to whole-tile vector reductions over the
VMEM-resident score tile (jnp.max/sum) — no warp shuffles exist on TPU;
block-table entries are scalar reads; the KV pool stays in "HBM" and only
valid tiles are sliced in.  interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8

NEG_INF = -1e30


def _attend_block(j, carry, *, q, bt_ref, ctx, kc_ref, vc_ref,
                  ks_ref, vs_ref, h, block_size, sm_scale):
    """Online-softmax update for KV block j.  carry = (m, l, acc)."""
    m_prev, l_prev, acc_prev = carry
    bid = bt_ref[0, j]
    k = pl.load(kc_ref, (bid, slice(None), h, slice(None)))  # [BS, D]
    v = pl.load(vc_ref, (bid, slice(None), h, slice(None)))
    if ks_ref is not None:
        k = fp8.e4m3_decode(k) * pl.load(ks_ref, (bid, slice(None), h))[:, None]
        v = fp8.e4m3_decode(v) * pl.load(vs_ref, (bid, slice(None), h))[:, None]
    s = jnp.dot(q, k.T) * sm_scale  # [Hg, BS]
    pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
    mask = (pos < ctx)[None, :]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # block-wise reduction over the VMEM tile = the paper's block_sum;
    # the explicit mask on p keeps fully-masked tiles (padding lanes in the
    # baseline's indiscriminate block walk) at exactly zero contribution.
    p = jnp.exp(s - m_new[:, None]) * mask
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_new = acc_prev * alpha[:, None] + jnp.dot(p, v)
    return m_new, l_new, acc_new


def _kernel(q_ref, bt_ref, ctx_ref, kc_ref, vc_ref, ks_ref, vs_ref, o_ref,
            *, block_size, max_blocks, valid_only, sm_scale):
    h = pl.program_id(1)
    ctx = ctx_ref[0]
    q = q_ref[0]  # [Hg, D]
    hg, d = q.shape
    body = functools.partial(
        _attend_block, q=q, bt_ref=bt_ref, ctx=ctx, kc_ref=kc_ref,
        vc_ref=vc_ref, ks_ref=ks_ref, vs_ref=vs_ref, h=h,
        block_size=block_size, sm_scale=sm_scale)
    init = (jnp.full((hg,), NEG_INF, jnp.float32),
            jnp.zeros((hg,), jnp.float32),
            jnp.zeros((hg, d), jnp.float32))
    if valid_only:
        # Opt-Pa, Eq. 9: touch only ceil(ctx / B) blocks.
        nblk = (ctx + block_size - 1) // block_size
        m, l, acc = jax.lax.fori_loop(0, nblk, body, init)
    else:
        # Baseline: walk every table entry (masked, numerically identical).
        m, l, acc = jax.lax.fori_loop(0, max_blocks, body, init)
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


def paged_attention(q, k_cache, v_cache, block_tables, ctx_lens,
                    k_scale=None, v_scale=None, *, groups,
                    valid_only, interpret=True):
    """Batched paged decode attention.

    q           : [B, Hq, D] f32 (the current token's queries)
    k/v_cache   : [NB, BS, Hk, D] (f32, or uint8 E4M3 codes with scales)
    block_tables: [B, MAXB] i32 (pool block id per logical block)
    ctx_lens    : [B] i32, tokens visible *including* the current one;
                  0 marks a padded batch lane (output = 0 there after the
                  l>=eps clamp, rust ignores those lanes)
    k/v_scale   : [NB, BS, Hk] f32 in FP8 mode
    groups      : H_q // H_k (Eq. 7); 1 = MHA
    valid_only  : Opt-Pa on/off

    Returns [B, Hq, D].
    """
    B, Hq, D = q.shape
    Hk = k_cache.shape[2]
    assert Hq == Hk * groups, (Hq, Hk, groups)
    max_blocks = block_tables.shape[1]
    fp8_mode = k_scale is not None

    kernel = functools.partial(
        _kernel, block_size=k_cache.shape[1], max_blocks=max_blocks,
        valid_only=valid_only, sm_scale=1.0 / (D ** 0.5))
    full = lambda a: pl.BlockSpec(a.shape, lambda b, h: (0,) * a.ndim)
    in_specs = [
        pl.BlockSpec((1, groups, D), lambda b, h: (b, h, 0)),   # q group
        pl.BlockSpec((1, max_blocks), lambda b, h: (b, 0)),     # table row
        pl.BlockSpec((1,), lambda b, h: (b,)),                  # ctx len
        full(k_cache), full(v_cache),
    ]
    args = [q, block_tables, ctx_lens, k_cache, v_cache]
    if fp8_mode:
        in_specs += [full(k_scale), full(v_scale)]
        args += [k_scale, v_scale]
    else:
        kernel = functools.partial(kernel)

    def wrapped(*refs):
        if fp8_mode:
            q_r, bt_r, ctx_r, kc_r, vc_r, ks_r, vs_r, o_r = refs
            kernel(q_r, bt_r, ctx_r, kc_r, vc_r, ks_r, vs_r, o_r)
        else:
            q_r, bt_r, ctx_r, kc_r, vc_r, o_r = refs
            kernel(q_r, bt_r, ctx_r, kc_r, vc_r, None, None, o_r)

    return pl.pallas_call(
        wrapped,
        grid=(B, Hk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, groups, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        interpret=interpret,
    )(*args)
