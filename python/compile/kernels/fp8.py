"""Software FP8 (E4M3FN) codec in pure jnp integer/float ops.

The paper's platform (DCU Z100) has no native FP8 units: "FP8 operations
are emulated via INT8 instructions" (§4.1).  We mirror that: KV-cache
entries are stored as uint8 E4M3FN bit patterns plus per-slot/per-head
f32 scales, and the encode/decode below runs *inside* the Pallas kernels
(Opt-KV, Eq. 6) using only ops the old xla_extension 0.5.1 HLO parser
understands (no f8 dtypes appear in the lowered module).

E4M3FN layout: 1 sign | 4 exponent (bias 7) | 3 mantissa.
No infinities; 0x7F/0xFF are NaN; max finite = 448; min subnormal = 2^-9.

Bit-exactness against ml_dtypes' float8_e4m3fn is enforced by
python/tests/test_fp8.py (all 256 decode patterns + randomized encode).
"""

import jax.numpy as jnp

E4M3_MAX = 448.0
_MIN_NORMAL_EXP = -6  # smallest normal exponent
_SUB_SCALE = 512.0  # 2^9 : subnormal quantum is 2^-9


def e4m3_round(x):
    """Round f32 values to the nearest representable E4M3 value (RNE).

    Saturates to +-448 (the `fn` convention for our pre-scaled inputs).
    Returns f32 holding exactly-representable E4M3 magnitudes.
    """
    x = jnp.asarray(x, jnp.float32)
    x = jnp.clip(x, -E4M3_MAX, E4M3_MAX)
    a = jnp.abs(x)
    # Exponent of the value; clip into the E4M3 normal/subnormal split.
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(2.0**-40))))
    e = jnp.clip(e, _MIN_NORMAL_EXP, 8)
    # Quantum: 2^(e-3) for normals (3 mantissa bits); 2^-9 in the subnormal
    # band (e pinned at -6 gives exactly 2^-9).
    step = jnp.exp2(e - 3.0)
    q = jnp.round(a / step) * step  # jnp.round is round-half-to-even
    return jnp.sign(x) * q


def e4m3_encode(x):
    """f32 -> uint8 E4M3FN bit patterns (saturating, RNE)."""
    x = jnp.asarray(x, jnp.float32)
    q = e4m3_round(x)
    sign = (q < 0) | ((q == 0) & (jnp.signbit(x)))
    a = jnp.abs(q)
    is_zero = a == 0
    is_sub = a < 2.0**_MIN_NORMAL_EXP
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(2.0**-40))))
    e = jnp.clip(e, _MIN_NORMAL_EXP, 8)
    # 3-bit mantissa field
    m_norm = a / jnp.exp2(e) * 8.0 - 8.0
    m_sub = a * _SUB_SCALE
    m = jnp.where(is_sub, m_sub, m_norm)
    ef = jnp.where(is_sub | is_zero, 0.0, e + 7.0)
    code = (
        jnp.where(sign, jnp.uint32(0x80), jnp.uint32(0))
        | (ef.astype(jnp.uint32) << 3)
        | m.astype(jnp.uint32)
    )
    return code.astype(jnp.uint8)


def e4m3_decode(code):
    """uint8 E4M3FN bit patterns -> f32."""
    code = jnp.asarray(code, jnp.uint8).astype(jnp.uint32)
    sign = (code >> 7) & 1
    ef = (code >> 3) & 0xF
    m = (code & 0x7).astype(jnp.float32)
    eff = ef.astype(jnp.float32)
    mag_sub = m / _SUB_SCALE
    mag_norm = jnp.exp2(eff - 7.0) * (1.0 + m / 8.0)
    mag = jnp.where(ef == 0, mag_sub, mag_norm)
    val = jnp.where(sign == 1, -mag, mag)
    # 0x7F / 0xFF are NaN in the fn encoding.
    return jnp.where((ef == 15) & (m == 7.0), jnp.float32(jnp.nan), val)


def quantize(x, axis=-1, eps=1e-12):
    """Dynamic per-slice symmetric quantization to E4M3 codes + f32 scale.

    `axis` is reduced for the amax; scale maps amax -> E4M3_MAX so the
    full exponent range is used (the paper's 'dynamic quantization').
    Returns (codes uint8, scale f32 with `axis` removed).
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=axis)
    scale = jnp.maximum(amax, eps) / E4M3_MAX
    codes = e4m3_encode(x / jnp.expand_dims(scale, axis))
    return codes, scale


def dequantize(codes, scale, axis=-1):
    """Inverse of `quantize` (Eq. 6: on-the-fly dequant in the read path)."""
    return e4m3_decode(codes) * jnp.expand_dims(scale, axis)
