"""Opt-KV write-path kernel (paper Alg. 1, phase 1 + Eq. 5).

`reshape_and_cache` analogue: scatter per-token K/V projections into the
paged pool at the slots chosen by the rust coordinator.  Slot -1 encodes
"skip" — the coordinator maps both padding lanes and SkipSet members
(Eq. 5: slot_idx < 0 ∨ slot_idx ∈ SkipSet) to -1, so the skip *policy*
lives in L3 and this kernel implements the mechanism.

In FP8 mode (Opt-KV) each written token is dynamically quantized per KV
head to E4M3 codes + an f32 scale (paper §3.1 "compressing valid blocks
into FP8 format").

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the CUDA original
scatters with one thread per element; here the grid is one program per
token, the token's [Hk, D] tile lives in VMEM, and the store is a single
dynamically-indexed (block, offset) tile store to the HBM-resident pool.
interpret=True everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8


def _kernel_f32(k_ref, v_ref, slot_ref, kc_ref, vc_ref, ko_ref, vo_ref,
                *, block_size):
    t = pl.program_id(0)
    slot = slot_ref[t]

    @pl.when(slot >= 0)
    def _():
        blk = slot // block_size
        off = slot % block_size
        ko_ref[blk, off, :, :] = k_ref[0]
        vo_ref[blk, off, :, :] = v_ref[0]


def _kernel_fp8(k_ref, v_ref, slot_ref, kc_ref, vc_ref, ks_ref, vs_ref,
                ko_ref, vo_ref, kso_ref, vso_ref, *, block_size):
    t = pl.program_id(0)
    slot = slot_ref[t]

    @pl.when(slot >= 0)
    def _():
        blk = slot // block_size
        off = slot % block_size
        kq, ks = fp8.quantize(k_ref[0], axis=-1)
        vq, vs = fp8.quantize(v_ref[0], axis=-1)
        ko_ref[blk, off, :, :] = kq
        vo_ref[blk, off, :, :] = vq
        kso_ref[blk, off, :] = ks
        vso_ref[blk, off, :] = vs


def kv_write(k_new, v_new, slot_mapping, k_cache, v_cache,
             k_scale=None, v_scale=None, *, interpret=True):
    """Write T new tokens into the paged KV pool.

    k_new/v_new : [T, Hk, D] f32
    slot_mapping: [T] i32 (global slot = block*BS + offset; -1 = skip)
    k_cache/v_cache: [NB, BS, Hk, D] (f32, or uint8 codes in FP8 mode)
    k_scale/v_scale: [NB, BS, Hk] f32 (FP8 mode only)

    Returns the updated cache arrays (same structure as inputs).  The cache
    operands are donated via input_output_aliases so XLA updates in place.
    """
    T, Hk, D = k_new.shape
    fp8_mode = k_scale is not None
    grid = (T,)
    tok_spec = pl.BlockSpec((1, Hk, D), lambda t: (t, 0, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda t: (0,) * a.ndim)

    if fp8_mode:
        kernel = functools.partial(_kernel_fp8, block_size=k_cache.shape[1])
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[tok_spec, tok_spec, full(slot_mapping),
                      full(k_cache), full(v_cache),
                      full(k_scale), full(v_scale)],
            out_specs=[full(k_cache), full(v_cache),
                       full(k_scale), full(v_scale)],
            out_shape=[
                jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
                jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
                jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
                jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
            ],
            input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
            interpret=interpret,
        )(k_new, v_new, slot_mapping, k_cache, v_cache, k_scale, v_scale)

    kernel = functools.partial(_kernel_f32, block_size=k_cache.shape[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tok_spec, tok_spec, full(slot_mapping),
                  full(k_cache), full(v_cache)],
        out_specs=[full(k_cache), full(v_cache)],
        out_shape=[
            jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
            jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
        ],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(k_new, v_new, slot_mapping, k_cache, v_cache)
