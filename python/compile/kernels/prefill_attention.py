"""Causal grouped prefill attention kernel.

One Pallas program per query head; the program's KV head is selected by
the Opt-GQA mapping h_k = h_q // groups (Eq. 7) directly in the BlockSpec
index map, so a KV head's tile is shared by its whole query group.

Prefill attends over the *fresh* (unquantized) K/V of the prompt — FP8
only applies to cached reads during decode, matching the reference stack
(vLLM computes prefill attention from the projection outputs, not the
cache).  Padding columns (>= seq_len) are masked; causality via a
position-triangle mask.  S is small (MAX_SEQ=128) so one program holds
the full [S, S] score tile in VMEM; for long-context deployments this
kernel would tile over query chunks exactly like the decode kernel tiles
over KV blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, sm_scale):
    q = q_ref[:, 0, :]  # [S, D]
    k = k_ref[:, 0, :]
    v = v_ref[:, 0, :]
    seq_len = len_ref[0]
    s = jnp.dot(q, k.T) * sm_scale  # [S, S]
    S = s.shape[0]
    pos = jax.lax.iota(jnp.int32, S)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] < seq_len)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    o_ref[:, 0, :] = jnp.dot(p / l, v)


def prefill_attention(q, k, v, seq_len, *, groups, interpret=True):
    """q: [S, Hq, D], k/v: [S, Hk, D], seq_len: [] or [1] i32 -> [S, Hq, D]."""
    S, Hq, D = q.shape
    Hk = k.shape[1]
    assert Hq == Hk * groups, (Hq, Hk, groups)
    seq_len = jnp.reshape(jnp.asarray(seq_len, jnp.int32), (1,))
    kernel = functools.partial(_kernel, sm_scale=1.0 / (D ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=(Hq,),
        in_specs=[
            pl.BlockSpec((S, 1, D), lambda h: (0, h, 0)),
            pl.BlockSpec((S, 1, D), lambda h: (0, h // groups, 0)),
            pl.BlockSpec((S, 1, D), lambda h: (0, h // groups, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((S, 1, D), lambda h: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Hq, D), jnp.float32),
        interpret=interpret,
    )(q, k, v, seq_len)
