"""L2: the LLaMA-family model (build-time JAX), calling the L1 kernels.

Three entry points share one parameter set:

  * `forward_prefill`  — serving prefill: writes the prompt's K/V into the
    paged pool (kv_write kernel) and returns logits for *every* position
    (needed by the ARC scoring protocol) — one sequence per call.
  * `forward_decode`   — serving decode: batched single-token step over the
    paged pool (kv_write + paged_attention kernels).
  * `forward_train`    — dense-attention training/uptraining forward used
    only by train.py (never lowered to an artifact).

Architecture: token embedding -> N x (RMSNorm -> RoPE attention -> add ->
RMSNorm -> SwiGLU -> add) -> RMSNorm -> lm_head.  Matches LLaMA up to
scale.  The OptConfig flags choose the KV projection set (MHA vs GQA),
the cache dtype (f32 vs E4M3 codes + scales), and the paged-attention
block loop policy; see presets.OPT_CONFIGS.
"""

import jax
import jax.numpy as jnp

from .presets import ModelPreset, OptConfig
from .kernels.kv_write import kv_write
from .kernels.paged_attention import paged_attention
from .kernels.prefill_attention import prefill_attention
from .kernels import ref as kref


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, *, base=10000.0):
    """Rotary embedding.  x: [..., T, H, D], positions: [..., T] i32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp2(
        -jnp.log2(jnp.float32(base)) * jnp.arange(half, dtype=jnp.float32)
        * 2.0 / d)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, w1, w2, w3):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def _kv_weights(params, i, gqa):
    suf = "gqa" if gqa else "mha"
    return params[f"l{i}.wk_{suf}"], params[f"l{i}.wv_{suf}"]


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def _merge_heads(x):
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


# ---------------------------------------------------------------------------
# serving: prefill
# ---------------------------------------------------------------------------

def forward_prefill(params, preset: ModelPreset, opt: OptConfig,
                    token_ids, seq_len, slot_mapping,
                    k_cache, v_cache, k_scale=None, v_scale=None,
                    *, interpret=True):
    """One-sequence prefill.

    token_ids   : [S] i32 (padded with PAD past seq_len)
    seq_len     : [] i32
    slot_mapping: [S] i32 global slots for each position (-1 past seq_len,
                  or SkipSet members under Opt-KV)
    caches      : stacked per-layer pools [L, NB, BS, Hk, D] (+ scales)

    Returns (logits [S, V], k_cache', v_cache'[, k_scale', v_scale']).
    """
    p, hd = preset, preset.head_dim
    hk = p.n_kv_heads(opt.gqa)
    groups = p.n_heads // hk
    fp8_mode = opt.fp8_kv
    positions = jnp.arange(token_ids.shape[0], dtype=jnp.int32)

    x = params["embed"][token_ids]  # [S, d]
    new_k, new_v, new_ks, new_vs = [], [], [], []
    for i in range(p.layers):
        h = rms_norm(x, params[f"l{i}.attn_norm"])
        wk, wv = _kv_weights(params, i, opt.gqa)
        q = _split_heads(h @ params[f"l{i}.wq"], p.n_heads, hd)
        k = _split_heads(h @ wk, hk, hd)
        v = _split_heads(h @ wv, hk, hd)
        q = rope(q, positions)
        k = rope(k, positions)
        # Opt-KV write path: scatter the prompt's K/V into the paged pool.
        if fp8_mode:
            kc, vc, ks, vs = kv_write(
                k, v, slot_mapping, k_cache[i], v_cache[i],
                k_scale[i], v_scale[i], interpret=interpret)
            new_ks.append(ks)
            new_vs.append(vs)
        else:
            kc, vc = kv_write(k, v, slot_mapping, k_cache[i], v_cache[i],
                              interpret=interpret)
        new_k.append(kc)
        new_v.append(vc)
        # Prefill attention runs over the fresh K/V (see module docstring).
        attn = prefill_attention(q, k, v, seq_len, groups=groups,
                                 interpret=interpret)
        x = x + _merge_heads(attn) @ params[f"l{i}.wo"]
        h = rms_norm(x, params[f"l{i}.ffn_norm"])
        x = x + swiglu(h, params[f"l{i}.w1"], params[f"l{i}.w2"],
                       params[f"l{i}.w3"])
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    out = (logits, jnp.stack(new_k), jnp.stack(new_v))
    if fp8_mode:
        out += (jnp.stack(new_ks), jnp.stack(new_vs))
    return out


# ---------------------------------------------------------------------------
# serving: decode
# ---------------------------------------------------------------------------

def forward_decode(params, preset: ModelPreset, opt: OptConfig,
                   token_ids, positions, block_tables, ctx_lens,
                   slot_mapping, k_cache, v_cache,
                   k_scale=None, v_scale=None, *, interpret=True):
    """Batched single-token decode step.

    token_ids   : [B] i32 (PAD in unused lanes)
    positions   : [B] i32 position of the new token
    block_tables: [B, MAXB] i32
    ctx_lens    : [B] i32 context length *including* the new token
                  (0 = padded lane)
    slot_mapping: [B] i32 slot for the new token's K/V (-1 = skip)
    caches      : [L, NB, BS, Hk, D] (+ scales [L, NB, BS, Hk])

    Returns (logits [B, V], caches'...).
    """
    p, hd = preset, preset.head_dim
    hk = p.n_kv_heads(opt.gqa)
    groups = p.n_heads // hk
    fp8_mode = opt.fp8_kv

    x = params["embed"][token_ids]  # [B, d]
    new_k, new_v, new_ks, new_vs = [], [], [], []
    for i in range(p.layers):
        h = rms_norm(x, params[f"l{i}.attn_norm"])
        wk, wv = _kv_weights(params, i, opt.gqa)
        q = _split_heads(h @ params[f"l{i}.wq"], p.n_heads, hd)
        k = _split_heads(h @ wk, hk, hd)
        v = _split_heads(h @ wv, hk, hd)
        # rope over a length-1 "sequence" per batch lane
        q = rope(q[:, None], positions[:, None])[:, 0]
        k = rope(k[:, None], positions[:, None])[:, 0]
        if fp8_mode:
            kc, vc, ks, vs = kv_write(
                k, v, slot_mapping, k_cache[i], v_cache[i],
                k_scale[i], v_scale[i], interpret=interpret)
            new_ks.append(ks)
            new_vs.append(vs)
            attn = paged_attention(q, kc, vc, block_tables, ctx_lens,
                                   ks, vs, groups=groups,
                                   valid_only=opt.valid_only,
                                   interpret=interpret)
        else:
            kc, vc = kv_write(k, v, slot_mapping, k_cache[i], v_cache[i],
                              interpret=interpret)
            attn = paged_attention(q, kc, vc, block_tables, ctx_lens,
                                   groups=groups, valid_only=opt.valid_only,
                                   interpret=interpret)
        new_k.append(kc)
        new_v.append(vc)
        x = x + _merge_heads(attn) @ params[f"l{i}.wo"]
        h = rms_norm(x, params[f"l{i}.ffn_norm"])
        x = x + swiglu(h, params[f"l{i}.w1"], params[f"l{i}.w2"],
                       params[f"l{i}.w3"])
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    out = (logits, jnp.stack(new_k), jnp.stack(new_v))
    if fp8_mode:
        out += (jnp.stack(new_ks), jnp.stack(new_vs))
    return out


# ---------------------------------------------------------------------------
# training forward (dense attention; never exported)
# ---------------------------------------------------------------------------

def forward_train(params, preset: ModelPreset, token_ids, lens, *, gqa):
    """token_ids: [B, S] i32, lens: [B] i32 -> logits [B, S, V]."""
    p, hd = preset, preset.head_dim
    hk = p.n_kv_heads(gqa)
    B, S = token_ids.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"][token_ids]
    for i in range(p.layers):
        h = rms_norm(x, params[f"l{i}.attn_norm"])
        wk, wv = _kv_weights(params, i, gqa)
        q = _split_heads(h @ params[f"l{i}.wq"], p.n_heads, hd)
        k = _split_heads(h @ wk, hk, hd)
        v = _split_heads(h @ wv, hk, hd)
        q = rope(q, positions)
        k = rope(k, positions)
        attn = kref.ref_dense_causal_attention(q, k, v, lens)
        x = x + _merge_heads(attn) @ params[f"l{i}.wo"]
        h = rms_norm(x, params[f"l{i}.ffn_norm"])
        x = x + swiglu(h, params[f"l{i}.w1"], params[f"l{i}.w2"],
                       params[f"l{i}.w3"])
    x = rms_norm(x, params["final_norm"])
    return x @ params["lm_head"]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(preset: ModelPreset, seed=0):
    from .presets import weight_shapes
    key = jax.random.PRNGKey(seed)
    shapes = weight_shapes(preset)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * (fan_in ** -0.5))
    return params
