"""Synthetic ARC-like 4-choice QA benchmark + training corpus.

Stands in for ARC-Easy / ARC-Challenge (paper §4.2): the accuracy
experiments (Tables 1-2) only need a 4-way MCQ task whose difficulty
separates model scale and whose answers are perturbed by numerical error
in the KV path.  We use grade-school arithmetic in the ARC answer format:

    easy      (ARC_E stand-in): 50% marked-value retrieval ("find the
              marked value", an induction-head task tiny transformers can
              acquire) + 50% 1-digit addition.  Accuracy ceiling ~62%.
    challenge (ARC_C stand-in): 25% marked + 75% 2-digit addition with
              carry (genuine computation, beyond these sims).  Ceiling ~43%.

The mix mirrors ARC's split semantics: ARC_E is largely solvable by
retrieval/surface cues, ARC_C defeats them.  Model scale differentiates
through the induction-circuit acquisition: the 7B-class sims' training
budget sits below the transition (near-chance, like the paper's 27-30%
7B scores), the 13B-class sims' above it (mid-range, like 40-71%).

Scoring protocol mirrors the standard single-token MCQ evaluation: the
model is shown "Q: ... A) .. B) .. C) .. D) ..\nAnswer:" and the choice
letter with the highest next-token log-prob wins (Eq. 13 accuracy).

Everything is seeded so python (corpus/eval generation) and rust (eval
loading via artifacts/arc_sim_*.json) agree exactly.
"""

import json

import numpy as np

from .presets import BOS_ID, EOS_ID, PAD_ID

LETTERS = "ABCD"


def _distractors(ans, rng):
    """Plausible wrong answers: off-by-one, off-by-ten, digit tricks."""
    cands = {ans + 1, ans - 1, ans + 10, ans - 10, ans + 2, ans - 2}
    s = str(ans)
    if len(s) == 2:
        cands.add(int(s[::-1]))  # digit swap
    cands = sorted(c for c in cands if c >= 0 and c != ans)
    rng.shuffle(cands)
    return cands[:3]


MARKED_FRAC = {"easy": 0.5, "challenge": 0.25}


def make_question(split, rng):
    """Returns dict(question, choices[4], answer_idx, kind, prompt, full)."""
    marked = rng.random() < MARKED_FRAC[split]
    if marked:
        ans = int(rng.integers(10, 100))
        q = "Q: find the marked value."
        kind = "marked"
    else:
        if split == "easy":
            a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
        elif split == "challenge":
            a, b = int(rng.integers(10, 100)), int(rng.integers(10, 100))
        else:
            raise ValueError(split)
        ans = a + b
        q = f"Q: {a}+{b}=?"
        kind = "arith"
    wrong = _distractors(ans, rng)
    while len(wrong) < 3:  # tiny-answer corner: pad with offset values
        cand = ans + 3 + len(wrong)
        if cand not in wrong:
            wrong.append(cand)
    answer_idx = int(rng.integers(0, 4))
    choices = wrong[:answer_idx] + [ans] + wrong[answer_idx:]
    choices = choices[:4]
    mark = ["" for _ in range(4)]
    if marked:
        mark[answer_idx] = "*"
    opts = " ".join(f"{LETTERS[i]}) {mark[i]}{choices[i]}" for i in range(4))
    prompt = f"{q} {opts}\nAnswer:"
    return {
        "question": q,
        "kind": kind,
        "choices": [str(c) for c in choices],
        "answer": answer_idx,
        "prompt": prompt,
        "full": prompt + " " + LETTERS[answer_idx],
    }


def encode(text, *, bos=True, eos=False):
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS_ID] + ids
    if eos:
        ids = ids + [EOS_ID]
    return ids


def decode(ids):
    return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


def training_batch(split_mix, batch, seqlen, rng):
    """Sample a padded LM batch.  split_mix: list of split names to mix.

    Returns (tokens [B, S] i32, lens [B] i32, loss_w [B, S] f32) where the
    answer-letter position carries extra loss weight (the capability the
    eval probes).
    """
    toks = np.full((batch, seqlen), PAD_ID, np.int32)
    lens = np.zeros(batch, np.int32)
    w = np.zeros((batch, seqlen), np.float32)
    for i in range(batch):
        split = split_mix[int(rng.integers(0, len(split_mix)))]
        s = make_question(split, rng)
        ids = encode(s["full"], bos=True, eos=True)[:seqlen]
        toks[i, : len(ids)] = ids
        lens[i] = len(ids)
        # next-token targets: weight 1 on ordinary tokens, extra on the
        # answer letter — the capability the ARC-sim eval probes
        w[i, : len(ids) - 1] = 1.0
        w[i, len(ids) - 3] = 5.0  # predicts the answer letter
    return toks, lens, w


def make_eval_set(split, n, seed):
    rng = np.random.default_rng(seed)
    qs = [make_question(split, rng) for _ in range(n)]
    return {
        "split": split,
        "seed": seed,
        "n": n,
        "letters": LETTERS,
        "questions": qs,
    }


def write_eval_sets(outdir, n=200, seed_easy=1234, seed_challenge=5678):
    import os

    paths = {}
    for split, seed in [("easy", seed_easy), ("challenge", seed_challenge)]:
        data = make_eval_set(split, n, seed)
        path = os.path.join(outdir, f"arc_sim_{split}.json")
        with open(path, "w") as f:
            json.dump(data, f)
        paths[split] = path
    return paths
